"""Disaggregated prefill/decode serving: KV block handoff invariants,
role-specialized engines, the two-stage router, per-label pool pressure
and role-split autoscaling.

Fast lane: engine pairs driven directly (prefill role -> KVHandoff ->
decode role) are checked bitwise against a unified engine per arch family
(GQA and MLA), plus refcount/leak accounting, prefix republish across the
pool boundary, fingerprint rejection and router lease semantics with
manual fake servers.  The full two-fleet kill/replay drills carry
@pytest.mark.slow.
"""

from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.images import ExecutableRegistry, PayloadImage
from repro.models.api import build_model
from repro.serving.dispatch import DisaggRouter, FleetDispatcher
from repro.serving.engine import (
    Request, ServeEngine, handoff_ineligible_reason,
)

ARCHS = ["smollm-360m", "minicpm3-4b"]        # GQA and MLA families


def _cfg_params(arch):
    cfg = get_smoke_config(arch)
    return cfg, build_model(cfg).init(jax.random.key(0))


def _reqs(cfg, n, seed=0, plen_lo=4, plen_hi=28, mnt=(5, 9)):
    # plen < 29 keeps the admission bucket <= 32, so bucket + budget fits
    # max_len=64 and every stream runs its FULL decode budget
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(plen_lo, plen_hi))
        out.append((i, rng.integers(0, cfg.vocab_size, size=plen,
                                    dtype=np.int64).astype(np.int32),
                    int(rng.choice(mnt))))
    return out

def _submit_all(eng, reqs, **kw):
    for rid, prompt, mnt in reqs:
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mnt, **kw))


def _disagg_streams(pf, dc, reqs) -> dict[int, list]:
    """Drive requests through a prefill-role engine, carry every exported
    handoff into a decode-role engine, and return the resumed streams."""
    exported0, imported0 = pf.prefills_exported, dc.handoffs_imported
    _submit_all(pf, reqs)
    pf.run()
    assert pf.prefills_exported - exported0 == len(reqs)
    for rid, prompt, mnt in reqs:
        h = pf.done[rid].handoff
        assert h is not None and h.first_token == pf.done[rid].tokens[0]
        dc.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mnt,
                          handoff=h))
    dc.run()
    assert dc.handoffs_imported - imported0 == len(reqs)
    return {rid: dc.done[rid].tokens for rid, _, _ in reqs}


@pytest.mark.parametrize("arch", ARCHS)
def test_disagg_bitwise_parity_vs_unified(arch):
    cfg, params = _cfg_params(arch)
    reqs = _reqs(cfg, 6, seed=1)

    uni = ServeEngine(cfg, params, slots=2, max_len=64)
    _submit_all(uni, reqs)
    uni.run()
    ref = {rid: uni.done[rid].tokens for rid, _, _ in reqs}

    pf = ServeEngine(cfg, params, slots=2, max_len=64, role="prefill")
    dc = ServeEngine(cfg, params, slots=2, max_len=64, role="decode")
    got = _disagg_streams(pf, dc, reqs)

    assert got == ref                      # bitwise: same tokens, all rids
    for rid, _, mnt in reqs:
        assert len(got[rid]) == mnt + 1    # admission token + decode budget
    assert pf.block_leaks() == 0 and dc.block_leaks() == 0


def test_refcount_balance_and_zero_leaks_after_churn():
    """Shared prefixes crossing the handoff, several waves of churn: every
    block must return to both pools (exporter frees at export, importer
    frees at eviction; the prefix caches hold only reclaimable refs)."""
    cfg, params = _cfg_params("smollm-360m")
    pf = ServeEngine(cfg, params, slots=2, max_len=64, role="prefill")
    dc = ServeEngine(cfg, params, slots=2, max_len=64, role="decode")
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, size=40,
                          dtype=np.int64).astype(np.int32)
    rid = 0
    for wave in range(3):
        reqs = []
        for i in range(4):
            if i % 2 == 0:                 # shared 40-token prefix + tail
                tail = rng.integers(0, cfg.vocab_size, size=4,
                                    dtype=np.int64).astype(np.int32)
                prompt = np.concatenate([shared, tail])
            else:
                prompt = rng.integers(0, cfg.vocab_size, size=9,
                                      dtype=np.int64).astype(np.int32)
            reqs.append((rid, prompt, 5))
            rid += 1
        _disagg_streams(pf, dc, reqs)
    assert pf.block_leaks() == 0
    assert dc.block_leaks() == 0
    # after the leak audit (prefix caches flushed) every block is free again
    assert pf.allocator.available_blocks == pf.allocator.capacity_blocks
    assert dc.allocator.available_blocks == dc.allocator.capacity_blocks


def test_imported_blocks_republish_into_decode_prefix_cache():
    """The handoff's chain-hash keys let the decode pool republish the
    imported full blocks: a second stream with the same prompt prefix
    must HIT in the decode pool's own PrefixCache — sharing crosses the
    pool boundary — while staying bitwise identical."""
    cfg, params = _cfg_params("smollm-360m")
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, size=40,
                          dtype=np.int64).astype(np.int32)
    reqs = [(i, shared.copy(), 5) for i in range(3)]

    uni = ServeEngine(cfg, params, slots=2, max_len=64)
    _submit_all(uni, reqs)
    uni.run()
    ref = {rid: uni.done[rid].tokens for rid, _, _ in reqs}

    pf = ServeEngine(cfg, params, slots=2, max_len=64, role="prefill")
    dc = ServeEngine(cfg, params, slots=2, max_len=64, role="decode")
    got = _disagg_streams(pf, dc, reqs)
    assert got == ref
    assert dc.prefix is not None and dc.prefix.hits > 0
    assert dc.block_leaks() == 0 and pf.block_leaks() == 0


def test_handoff_fingerprint_mismatch_rejected():
    """A GQA pool's handoff must not scatter into an MLA pool (different
    paged leaves entirely) — submit rejects on the arch fingerprint."""
    gqa_cfg, gqa_params = _cfg_params("smollm-360m")
    mla_cfg, mla_params = _cfg_params("minicpm3-4b")
    pf = ServeEngine(gqa_cfg, gqa_params, slots=2, max_len=64,
                     role="prefill")
    reqs = _reqs(gqa_cfg, 1, seed=2)
    _submit_all(pf, reqs)
    pf.run()
    h = pf.done[0].handoff
    dc = ServeEngine(mla_cfg, mla_params, slots=2, max_len=64,
                     role="decode")
    with pytest.raises(ValueError, match="fingerprint"):
        dc.submit(Request(rid=0, prompt=reqs[0][1], max_new_tokens=4,
                          handoff=h))
    assert pf.block_leaks() == 0


def test_role_validation_and_spec_forced_off():
    cfg, params = _cfg_params("smollm-360m")
    pf = ServeEngine(cfg, params, slots=2, max_len=64, role="prefill")
    dc = ServeEngine(cfg, params, slots=2, max_len=64, role="decode")
    # a decode-role engine only accepts handoff-carrying requests
    with pytest.raises(ValueError, match="handoff"):
        dc.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=2))
    # a prefill-role engine never imports
    _submit_all(pf, _reqs(cfg, 1, seed=3))
    pf.run()
    h = pf.done[0].handoff
    with pytest.raises(ValueError):
        pf.submit(Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=2, handoff=h))
    # draft KV does not ride the handoff: spec is forced off per role
    sp = ServeEngine(cfg, params, slots=2, max_len=64, role="prefill",
                     spec="draft")
    assert sp.spec == "off" and "role" in sp.spec_fallback_reason
    # attention-free archs cannot hand off KV block chains at all
    ssm_cfg, ssm_params = _cfg_params("mamba2-370m")
    assert handoff_ineligible_reason(
        ssm_cfg, "paged") is not None
    with pytest.raises(ValueError, match="handoff"):
        ServeEngine(ssm_cfg, ssm_params, slots=2, max_len=64,
                    role="prefill")


def test_payload_image_role_in_key_and_factory():
    img_u = PayloadImage("smollm-360m", "smoke", "serve")
    img_p = dataclasses.replace(img_u, role="prefill")
    img_d = dataclasses.replace(img_u, role="decode")
    assert len({img_u.key(), img_p.key(), img_d.key()}) == 3
    reg = ExecutableRegistry()
    exe = reg.pull(img_p)
    eng = exe.fn(exe.make_inputs(jax.random.key(0)))
    # a prefill-only image never wires (or compiles) the decode step
    assert eng.role == "prefill"
    assert eng._step_fn is None and eng._prefill is not None
    exe_d = reg.pull(img_d)
    eng_d = exe_d.fn(exe_d.make_inputs(jax.random.key(0)))
    assert eng_d.role == "decode"
    assert eng_d._prefill is None and eng_d._step_fn is not None


# ---------------------------------------------------------------------------
# DisaggRouter: two-stage leases with manual fake servers
# ---------------------------------------------------------------------------

def test_router_forwards_handoff_with_original_submit_time():
    r = DisaggRouter(name="t-fwd", lease_ttl=1.0)
    try:
        r.submit({"rid": 0, "prompt": [1, 2, 3], "max_new_tokens": 4})
        r.seal()
        (e,) = r.prefill.fetch("pf-0", max_n=1, timeout=2.0)
        h = object()                       # sentinel handoff payload
        assert r.prefill.complete("pf-0", 0, [7], first_token_s=0.01,
                                  handoff=h)
        (d,) = r.decode.fetch("dc-0", max_n=1, timeout=2.0)
        assert d["rid"] == 0
        assert d["handoff"] is h           # the payload rides the arena
        # end-to-end TTFT zero: the ORIGINAL submit time, not forward time
        assert d["submitted_s"] == e["submitted_s"]
        assert d["prefill_server"] == "pf-0"
        assert r.decode.complete("dc-0", 0, [7, 8, 9])
        assert r.wait_all(timeout=10.0)
        assert r.results() == {0: [7, 8, 9]}
        st = r.stats()
        assert st["prefill"]["completed"] == 1
        assert st["decode"]["completed"] == 1
    finally:
        r.close()


def test_router_decode_requeue_replays_from_handoff():
    """A dead decode pilot's lease expires and the SAME handoff re-leases
    to a survivor — the prompt is never re-prefilled."""
    r = DisaggRouter(name="t-requeue", lease_ttl=0.25)
    try:
        r.submit({"rid": 0, "prompt": [1, 2, 3], "max_new_tokens": 4})
        r.seal()
        (e,) = r.prefill.fetch("pf-0", max_n=1, timeout=2.0)
        h = object()
        r.prefill.complete("pf-0", 0, [5], handoff=h)
        (d1,) = r.decode.fetch("dc-dead", max_n=1, timeout=2.0)
        assert d1["handoff"] is h
        # dc-dead never renews: the reaper requeues after the TTL
        got = []
        deadline = time.monotonic() + 10.0
        while not got and time.monotonic() < deadline:
            got = r.decode.fetch("dc-live", max_n=1, timeout=0.2)
        assert got, "expired decode lease never requeued"
        assert got[0]["rid"] == 0 and got[0]["handoff"] is h
        r.decode.complete("dc-live", 0, [5, 6])
        assert r.wait_all(timeout=10.0)
        assert r.results() == {0: [5, 6]}
    finally:
        r.close()


def test_pool_pressure_reports_per_label():
    p = FleetDispatcher(name="t-labels", lease_ttl=5.0)
    try:
        p.announce("s-pf", labels={"pool": "prefill"})
        p.announce("s-dc", labels={"pool": "decode"})
        p.submit({"rid": 0, "prompt": [1], "max_new_tokens": 1})
        p.submit({"rid": 1, "prompt": [2], "max_new_tokens": 1})
        (e0,) = p.fetch("s-pf", max_n=1, timeout=2.0)
        (e1,) = p.fetch("s-dc", max_n=1, timeout=2.0)
        p.complete("s-pf", e0["rid"], [9], first_token_s=0.01)
        p.complete("s-dc", e1["rid"], [9], first_token_s=1.0)
        p.report_telemetry("s-pf", {"kv_memory_utilization": 0.9,
                                    "blocked_admissions": 3, "slots": 2,
                                    "prefills_exported": 5})
        p.report_telemetry("s-dc", {"kv_memory_utilization": 0.2,
                                    "blocked_admissions": 0, "slots": 4,
                                    "handoffs_imported": 5})
        pp = p.pool_pressure()
        bl = pp["by_label"]
        assert set(bl) == {"prefill", "decode"}
        # TTFT split per label, not blended across roles
        assert bl["prefill"]["ttft_p99_s"] == pytest.approx(0.01)
        assert bl["decode"]["ttft_p99_s"] == pytest.approx(1.0)
        assert bl["prefill"]["kv_memory_utilization"] == 0.9
        assert bl["decode"]["kv_memory_utilization"] == 0.2
        assert bl["prefill"]["blocked_by_server"] == {"s-pf": 3}
        assert bl["decode"]["blocked_by_server"] == {"s-dc": 0}
        assert bl["prefill"]["slots_per_server"] == 2.0
        assert bl["decode"]["slots_per_server"] == 4.0
        assert bl["prefill"]["prefills_exported"] == 5
        assert bl["decode"]["handoffs_imported"] == 5
        # the blended top-level view still exists (max over healthy)
        assert pp["kv_memory_utilization"] == 0.9
    finally:
        p.close()


# ---------------------------------------------------------------------------
# role-split autoscaling: each scaler reads only its label's slice
# ---------------------------------------------------------------------------

class _StubFleet:
    def __init__(self, n):
        self.n = n
        self.ups: list[int] = []
        self.sim = SimpleNamespace(repo=SimpleNamespace(
            stats=lambda: {"queued": 0, "leased": 0, "pilots": 0},
            scheduler_metrics=lambda: {"match_p50_us": 0,
                                       "match_p99_us": 0}))

    def size(self):
        return self.n

    def draining(self):
        return 0

    def scale_up(self, n):
        self.n += n
        self.ups.append(n)
        return [object()] * n

    def scale_down(self, n):
        self.n -= n
        return []


def test_autoscaler_pool_label_sizes_roles_independently():
    """Same pool snapshot, two scalers: only the role whose label slice
    shows KV pressure scales up — the blended view would grow both."""
    from repro.core.autoscaler import AutoscalePolicy, FleetAutoscaler

    pp = {
        "queued": 4, "leased": 0, "sick_servers": 0,
        "kv_memory_utilization": 0.99,        # blended view: looks hot
        "blocked_admissions": 3,
        "blocked_by_server": {"s-pf": 3},
        "slots_per_server": 2.0, "tokens_per_step": 0.0,
        "acceptance_rate": 0.0,
        "by_label": {
            "prefill": {"kv_memory_utilization": 0.99,
                        "blocked_admissions": 3,
                        "blocked_by_server": {"s-pf": 3},
                        "sick_servers": 0, "slots_per_server": 2.0,
                        "tokens_per_step": 0.0},
            "decode": {"kv_memory_utilization": 0.10,
                       "blocked_admissions": 0,
                       "blocked_by_server": {},
                       "sick_servers": 0, "slots_per_server": 2.0,
                       "tokens_per_step": 0.0},
        },
    }
    pool = SimpleNamespace(name="stub", pool_pressure=lambda: dict(pp))
    policy = AutoscalePolicy(min_pilots=0, max_pilots=8, slots_per_pilot=2,
                             kv_high_water=0.92)
    clk = [100.0]
    scalers = {}
    for label in ("prefill", "decode"):
        fleet = _StubFleet(2)              # util = 4 / (2*2): in band
        scalers[label] = (fleet, FleetAutoscaler(
            fleet, None, pool=pool, pool_label=label, policy=policy,
            clock=lambda: clk[0]))
    d_pf = scalers["prefill"][1].tick()
    d_dc = scalers["decode"][1].tick()
    assert d_pf is not None and d_pf.direction == "up"   # its slice is hot
    assert "kv pressure" in d_pf.reason
    assert d_dc is None                                  # its slice is cool
    assert scalers["prefill"][0].n == 3
    assert scalers["decode"][0].n == 2


# ---------------------------------------------------------------------------
# the full thing: two fleets, kill one pilot per stage, bitwise replay
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_fleet_disagg_kill_replay_bitwise(arch):
    from repro.launch.serve import make_trace, serve_disagg

    cfg, params = _cfg_params(arch)
    trace = make_trace(cfg.vocab_size, 10, max_len=64, seed=3)
    out = serve_disagg(arch, 10, prefill_pilots=2, decode_pilots=2,
                       slots=2, max_len=64, lease_ttl=0.5,
                       fail_prefill_at=2, fail_decode_at=4, trace=trace)
    assert out["drained"]
    assert out["leaked_blocks"] == 0
    assert len(out["results"]) == 10

    # unified single-engine reference over the SAME trace (image seed 0)
    uni = ServeEngine(cfg, params, slots=2, max_len=64)
    uni.run_trace(trace)
    ref = {r.rid: r.tokens for r in uni.done.values()}
    assert {rid: list(t) for rid, t in out["results"].items()} == ref
