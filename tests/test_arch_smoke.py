"""Per-arch smoke tests (required): reduced config, one forward/train step
on CPU, asserting output shapes + no NaNs; plus a decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config, list_archs
from repro.launch.steps import init_train_state, make_train_step
from repro.models.api import build_model, init_decode_state
from repro.optim.adamw import OptimConfig


# the fast lane keeps one representative arch; the full per-arch sweep is
# heavyweight (jamba alone jits ~30 s) and runs under -m "slow or not slow"
FAST_ARCHS = {"smollm-360m"}


def _archs(archs):
    return [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


def _batch(cfg, B=2, S=64):
    n_extra = cfg.frontend_tokens if cfg.family in ("vlm", "audio") else 0
    toks = S - (n_extra if cfg.family == "vlm" else 0)
    b = {
        "tokens": jnp.arange(B * toks, dtype=jnp.int32).reshape(B, toks)
        % cfg.vocab_size,
        "targets": (jnp.arange(B * toks, dtype=jnp.int32).reshape(B, toks) + 1)
        % cfg.vocab_size,
    }
    if n_extra:
        b["frontend"] = jnp.full((B, n_extra, cfg.d_model), 0.01, jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", _archs(list_archs()))
def test_forward_and_shapes(arch, rng_key):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(rng_key)
    batch = _batch(cfg)
    loss, metrics = jax.jit(bundle.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", _archs(list_archs()))
def test_one_train_step(arch, rng_key):
    cfg = get_smoke_config(arch)
    step = jax.jit(make_train_step(cfg, OptimConfig(total_steps=100)))
    state = init_train_state(cfg, rng_key)
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                          state["params"], new_state["params"])
    assert max(jax.tree.leaves(deltas)) > 0
    # every param leaf stays finite
    for leaf in jax.tree.leaves(new_state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", _archs(list_archs()))
def test_decode_step(arch, rng_key):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(rng_key)
    B, T = 2, 32
    state = init_decode_state(cfg, B, T)
    logits, state = jax.jit(bundle.decode)(params, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # pos is per-row (continuous batching); lockstep rows advance together
    np.testing.assert_array_equal(np.asarray(state["pos"]), np.ones(B))
    # second step advances
    logits2, state = jax.jit(bundle.decode)(params, state)
    np.testing.assert_array_equal(np.asarray(state["pos"]), np.full(B, 2))
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


@pytest.mark.parametrize("arch", _archs(["smollm-360m", "mamba2-370m",
                                         "mixtral-8x7b", "whisper-small",
                                         "minicpm3-4b"]))
def test_prefill_matches_decode(arch, rng_key):
    """Prefilling S tokens then decoding must agree with pure step-by-step
    decode at the same positions (cache-correctness invariant)."""
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(rng_key)
    B, S, T = 1, 8, 24
    toks = (jnp.arange(S, dtype=jnp.int32)[None] * 7 + 3) % cfg.vocab_size
    batch = {"tokens": toks}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = jnp.full((B, cfg.frontend_tokens, cfg.d_model),
                                     0.01, jnp.bfloat16)
    logits_p, cache = jax.jit(bundle.prefill)(params, batch)

    # step-by-step decode from an empty cache over the same tokens
    state = init_decode_state(cfg, B, S + (cfg.frontend_tokens
                                           if cfg.family == "audio" else 0))
    if cfg.family == "audio":
        pytest.skip("encdec prefill consumes frames; decode-only parity "
                    "is covered by test_decode_step")
    state = {**state, "token": toks[:, :1]}
    logits_d = None
    for i in range(S):
        logits_d, state = jax.jit(bundle.decode)(params, state)
        if i + 1 < S:
            state = {**state, "token": toks[:, i + 1:i + 2]}
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
        rtol=0.1, atol=0.15)
