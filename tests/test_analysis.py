"""The concurrency-analysis layer analyzed: auditor, lint, fuzzer.

The analyzer must itself be trustworthy — a lock auditor with false
positives gets suppressed into uselessness, and one with false negatives
is worse than none.  These tests pin both directions: synthetic
deadlock cycles ARE detected (with witness stacks naming the acquiring
functions), RLock reentrancy and the repo's legal ordering are NOT
flagged, every lint rule has a positive and a negative fixture, and the
schedule fuzzer's injected-preemption sequence is a pure function of its
seed.

Each test installs a PRIVATE auditor (they nest: the session-wide
``--concurrency-audit`` auditor, if any, is restored on exit), so the
deliberate violations below never fail the session audit.
"""

import threading
import time

import pytest

from repro.analysis.fuzz import ScheduleFuzzer, six_server_stress
from repro.analysis.lint import lint_source
from repro.analysis.locks import (
    RANK_POOL,
    RANK_REPO,
    LockAuditor,
    audit_callback,
    make_condition,
    make_lock,
    make_rlock,
)


# ---------------------------------------------------------------------------
# lock auditor
# ---------------------------------------------------------------------------

def _take_ab_then_ba(a, b):
    """Two acquisition orders of the same pair — the textbook deadlock."""
    with a:
        with b:
            pass
    with b:
        with a:
            pass


def test_cycle_detected_with_witness_stacks():
    a = make_lock("test.cycle-A")
    b = make_lock("test.cycle-B")
    with LockAuditor() as aud:
        _take_ab_then_ba(a, b)
    cycles = aud.cycles()
    ours = [cyc for cyc in cycles
            if {e["src"] for e in cyc} >= {"test.cycle-A", "test.cycle-B"}]
    assert ours, f"A<->B cycle not detected (cycles={cycles})"
    cyc = ours[0]
    pairs = {(e["src"], e["dst"]) for e in cyc}
    assert ("test.cycle-A", "test.cycle-B") in pairs
    assert ("test.cycle-B", "test.cycle-A") in pairs
    # the witness stack names the function that created the ordering
    for e in cyc:
        assert "_take_ab_then_ba" in e["stack"], e["stack"]
    # and the formatted report carries it for humans
    assert "_take_ab_then_ba" in aud.format_report()


def test_no_cycle_for_consistent_order():
    a = make_lock("test.ord-A")
    b = make_lock("test.ord-B")
    with LockAuditor() as aud:
        for _ in range(3):
            with a:
                with b:
                    pass
    assert not [cyc for cyc in aud.cycles()
                if {e["src"] for e in cyc} & {"test.ord-A", "test.ord-B"}]


def test_rlock_reentrancy_not_a_false_positive():
    rl = make_rlock("test.reentrant")
    with LockAuditor() as aud:
        with rl:
            with rl:            # nested re-acquire: NOT an ordering event
                with rl:
                    pass
    assert not aud.violations
    # no self-edge was recorded
    assert not [e for e in aud.edges()
                if e["src"] == e["dst"] == "test.reentrant"]


def test_nonreentrant_reacquire_raises_and_records():
    lk = make_lock("test.self-deadlock")
    with LockAuditor() as aud:
        with lk:
            with pytest.raises(RuntimeError, match="self-deadlock"):
                lk.acquire()
    kinds = [v["kind"] for v in aud.violations]
    assert "self-deadlock" in kinds


def test_hierarchy_rank_violation_flagged():
    repo = make_lock("test.rank-repo", rank=RANK_REPO)
    pool = make_lock("test.rank-pool", rank=RANK_POOL)
    with LockAuditor() as aud:
        with pool:              # pool -> repo: the documented order
            with repo:
                pass
        assert not [v for v in aud.violations
                    if v["kind"] == "lock-hierarchy"]
        with repo:              # repo -> pool: inverted
            with pool:
                pass
    bad = [v for v in aud.violations if v["kind"] == "lock-hierarchy"]
    assert bad and "test.rank-pool" in bad[0]["message"]


def test_wait_under_foreign_lock_flagged_and_self_wait_clean():
    other_lock = make_lock("test.wait-other")
    cond = make_condition(name="test.wait-cond")
    with LockAuditor() as aud:
        with cond:              # the legal shape: wait on yourself alone
            cond.wait(timeout=0.01)
        assert not [v for v in aud.violations
                    if v["kind"] == "wait-under-lock"]
        with other_lock:
            with cond:
                # lint: allow[blocking-under-lock] -- the fixture: waiting while holding a *foreign* lock is exactly what the runtime check must flag
                cond.wait(timeout=0.01)
    bad = [v for v in aud.violations if v["kind"] == "wait-under-lock"]
    assert bad and "test.wait-other" in bad[0]["message"]


def test_callback_under_lock_flagged():
    lk = make_lock("test.cb-lock")
    with LockAuditor() as aud:
        audit_callback("test:unlocked")      # held-set empty: fine
        assert not aud.violations
        with lk:
            audit_callback("test:locked")
    bad = [v for v in aud.violations if v["kind"] == "callback-under-lock"]
    assert bad and "test:locked" in bad[0]["message"]


def test_tracked_condition_wakeup_roundtrip():
    """The stdlib Condition machinery must work unchanged over tracked
    locks (notify wakes a waiter; the lock is correctly reacquired)."""
    cond = make_condition(name="test.roundtrip")
    box = []

    def consumer():
        with cond:
            while not box:
                if not cond.wait(timeout=5.0):
                    return
            box.append("consumed")

    t = threading.Thread(target=consumer, name="test-cond-consumer")
    with LockAuditor() as aud:
        t.start()
        time.sleep(0.05)
        with cond:
            box.append("produced")
            cond.notify_all()
        t.join(timeout=5.0)
    assert not t.is_alive()
    assert box == ["produced", "consumed"]
    assert not aud.violations


def test_completion_hook_fires_outside_pool_lock():
    """Regression for the dispatch fix: on_complete used to fire inside
    the pool lock — a hook touching the pool (as the DisaggRouter's
    forward does with its decode pool) would self-deadlock.  Now the hook
    runs lock-free: calling back into pool.stats() succeeds and the
    auditor records zero callback-under-lock violations."""
    from repro.serving.dispatch import FleetDispatcher

    seen = []
    with LockAuditor() as aud:
        pool = FleetDispatcher(name="test-hook-pool", lease_ttl=5.0)
        try:
            pool.on_complete = lambda rec, handoff: seen.append(
                (rec.rid, pool.stats()["completed"]))
            pool.submit({"rid": 0, "prompt": [1], "max_new_tokens": 1})
            got = pool.fetch("srv", timeout=1.0)
            assert [e["rid"] for e in got] == [0]
            assert pool.complete("srv", 0, [7, 8, 9])
            pool.seal()
            assert pool.wait_all(timeout=5.0)
        finally:
            pool.close()
    assert seen and seen[0][0] == 0
    assert not [v for v in aud.violations
                if v["kind"] == "callback-under-lock"]
    assert not aud.cycles()


# ---------------------------------------------------------------------------
# lint rules: one positive + one negative fixture per rule
# ---------------------------------------------------------------------------

def _rules(findings, *, suppressed=None):
    return [f.rule for f in findings
            if suppressed is None or f.suppressed == suppressed]


def test_lint_bare_lock_positive_and_negative():
    bad = "import threading\nlk = threading.Lock()\n"
    assert "bare-lock" in _rules(lint_source(bad, "src/repro/x.py"))
    bad2 = "from threading import RLock\nlk = RLock()\n"
    assert "bare-lock" in _rules(lint_source(bad2, "src/repro/x.py"))
    good = ("from repro.analysis.locks import make_lock\n"
            "lk = make_lock('x')\n")
    assert not lint_source(good, "src/repro/x.py")
    # the factory module itself is exempt
    exempt = "import threading\nlk = threading.Lock()\n"
    assert not lint_source(exempt, "src/repro/analysis/locks.py")


def test_lint_wallclock_in_step_builder():
    bad = ("import time\n"
           "def make_engine_step(cfg):\n"
           "    t = time.time()\n"
           "    return t\n")
    assert "wallclock-in-step" in _rules(lint_source(bad, "x.py"))
    good = ("import time\n"
            "def make_engine_step(cfg):\n"
            "    t = time.monotonic()\n"     # monotonic is host-side, fine
            "    return t\n"
            "def helper():\n"
            "    return time.time()\n")      # not a step builder
    assert not lint_source(good, "x.py")


def test_lint_one_transfer_scoped_to_engine_step_paths():
    bad = ("import jax\n"
           "class ServeEngine:\n"
           "    def step(self):\n"
           "        return jax.device_get(self.x)\n")
    path = "src/repro/serving/engine.py"
    assert "one-transfer" in _rules(lint_source(bad, path))
    itemy = ("class ServeEngine:\n"
             "    def step(self):\n"
             "        return self.x.item()\n")
    assert "one-transfer" in _rules(lint_source(itemy, path))
    # same code outside engine.py: out of scope
    assert not lint_source(bad, "src/repro/serving/other.py")
    # non-step methods of the engine may transfer freely
    good = ("import jax\n"
            "class ServeEngine:\n"
            "    def drain(self):\n"
            "        return jax.device_get(self.x)\n")
    assert not lint_source(good, path)


def test_lint_blocking_under_lock():
    bad = ("import time\n"
           "def f(self):\n"
           "    with self._lock:\n"
           "        time.sleep(0.1)\n")
    assert "blocking-under-lock" in _rules(lint_source(bad, "x.py"))
    joiny = ("def f(self, t):\n"
             "    with self._lock:\n"
             "        t.join()\n")
    assert "blocking-under-lock" in _rules(lint_source(joiny, "x.py"))
    # waiting on a FOREIGN condition under a lock is flagged
    foreign = ("def f(self):\n"
               "    with self._lock:\n"
               "        self._cond.wait()\n")
    assert "blocking-under-lock" in _rules(lint_source(foreign, "x.py"))
    # the legal shape: a condition waiting on itself, nothing else held
    good = ("def f(self):\n"
            "    with self._cond:\n"
            "        self._cond.wait()\n")
    assert not lint_source(good, "x.py")
    # sleep outside the with block: fine
    good2 = ("import time\n"
             "def f(self):\n"
             "    with self._lock:\n"
             "        x = 1\n"
             "    time.sleep(0.1)\n")
    assert not lint_source(good2, "x.py")


def test_lint_suppression_requires_justification():
    code = ("import threading\n"
            "a = threading.Lock()  # lint: allow[bare-lock] -- test fixture\n"
            "b = threading.Lock()  # lint: allow[bare-lock]\n")
    fs = lint_source(code, "src/repro/x.py")
    assert _rules(fs, suppressed=True) == ["bare-lock"]
    unsup = [f for f in fs if not f.suppressed]
    assert {f.rule for f in unsup} == {"bare-lock", "bad-suppression"}
    # suppression on the line above works too
    above = ("import threading\n"
             "# lint: allow[bare-lock] -- fixture\n"
             "a = threading.Lock()\n")
    assert not [f for f in lint_source(above, "src/repro/x.py")
                if not f.suppressed]
    # an allow for a DIFFERENT rule does not suppress
    wrong = ("import threading\n"
             "a = threading.Lock()  # lint: allow[one-transfer] -- nope\n")
    assert "bare-lock" in _rules(
        [f for f in lint_source(wrong, "src/repro/x.py")
         if not f.suppressed])


# ---------------------------------------------------------------------------
# schedule fuzzer
# ---------------------------------------------------------------------------

def _scripted_trace(seed: int, thread_name: str = "fuzz-det") -> list:
    """Run a fixed single-thread lock workload under the fuzzer and
    return that thread's decision sequence."""
    fz = ScheduleFuzzer(seed, p_preempt=0.3, sleep_s=0.0)
    a = make_lock("test.det-A")
    b = make_lock("test.det-B")

    def work():
        with fz.auditor():
            for _ in range(60):
                with a:
                    with b:
                        pass

    t = threading.Thread(target=work, name=thread_name)
    t.start()
    t.join(timeout=10.0)
    assert not t.is_alive()
    return fz.decisions[thread_name]


def test_fuzzer_seed_determinism():
    t1 = _scripted_trace(1234)
    t2 = _scripted_trace(1234)
    assert t1 == t2 and len(t1) >= 120
    assert sum(t1) > 0, "p=0.3 over 240 boundaries must preempt sometimes"
    t3 = _scripted_trace(4321)
    assert t3 != t1
    # the sequence is per-thread: a different thread name reseeds
    t4 = _scripted_trace(1234, thread_name="fuzz-det-other")
    assert t4 != t1


def test_fuzz_stress_race_small():
    """One fuzzed six-server stress race end to end (small N so the fast
    lane stays fast) — asserts exactly-once settlement, zero stranded
    leases, zero block leaks, zero cycles internally."""
    r = six_server_stress(7, n_requests=10, timeout=60.0)
    assert r["completed"] == 10
    assert r["preemptions"] > 0
    assert r["lock_acquisitions"] > 0
