"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp ref oracles
(interpret mode on CPU, per the assignment)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.rmsnorm.ops import rmsnorm_fused
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

KEY = jax.random.key(42)


def _rand(shape, dtype, k, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, k), shape,
                              jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [
    jnp.float32,
    # bf16 sweeps double kernel-test wall time; fast lane keeps f32
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("B,S,T,H,K,Dh,window", [
    (2, 128, 128, 4, 2, 64, None),
    (1, 256, 256, 8, 1, 64, None),       # MQA
    (2, 96, 96, 6, 3, 32, None),         # unaligned
    (1, 192, 192, 4, 4, 128, 64),        # SWA
    (1, 64, 256, 2, 2, 64, None),        # T > S (continuation)
])
def test_flash_attention_sweep(B, S, T, H, K, Dh, window, dtype):
    q = _rand((B, S, H, Dh), dtype, 1)
    k = _rand((B, T, K, Dh), dtype, 2)
    v = _rand((B, T, K, Dh), dtype, 3)
    off = T - S
    out = flash_attention(q, k, v, causal=True, window=window, q_offset=off,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=2e-2)


def test_flash_attention_noncausal():
    q = _rand((1, 64, 4, 32), jnp.float32, 4)
    k = _rand((1, 128, 2, 32), jnp.float32, 5)
    v = _rand((1, 128, 2, 32), jnp.float32, 6)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# decode attention (flash-decode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [
    jnp.float32,
    # bf16 sweeps double kernel-test wall time; fast lane keeps f32
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("B,T,H,K,Dh", [
    (2, 256, 4, 2, 64),
    (1, 512, 8, 8, 128),
    (3, 160, 6, 3, 32),
    (2, 128, 4, 1, 64),                  # MQA
])
def test_decode_attention_sweep(B, T, H, K, Dh, dtype):
    q = _rand((B, H, Dh), dtype, 7)
    kc = _rand((B, T, K, Dh), dtype, 8)
    vc = _rand((B, T, K, Dh), dtype, 9)
    lens = jnp.asarray([T, T // 3, 1][:B] + [T] * max(0, B - 3), jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_t=64)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=2e-2)


def test_decode_attention_ragged_positions():
    """Continuous-batching shape: every batch row is an independent request
    at its own position, so per-row KV lengths are fully ragged — a
    freshly-admitted row (short prefix) next to a nearly-full one, with
    lengths off the tile boundary."""
    B, T, H, K, Dh = 5, 160, 4, 2, 32
    q = _rand((B, H, Dh), jnp.float32, 17)
    kc = _rand((B, T, K, Dh), jnp.float32, 18)
    vc = _rand((B, T, K, Dh), jnp.float32, 19)
    lens = jnp.asarray([160, 1, 33, 97, 17], jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_t=32)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=2e-2)
    # row independence: changing the OTHER rows' lengths must not change a
    # given row's output (each row masks only its own KV tail)
    lens2 = jnp.asarray([160, 90, 2, 5, 17], jnp.int32)
    out2 = decode_attention(q, kc, vc, lens2, block_t=32)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out2[0]))
    np.testing.assert_array_equal(np.asarray(out[4]), np.asarray(out2[4]))


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [
    jnp.float32,
    # bf16 sweeps double kernel-test wall time; fast lane keeps f32
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("b,S,H,P,G,N,chunk", [
    (2, 256, 4, 64, 1, 128, 64),
    (1, 192, 8, 32, 2, 64, 64),          # grouped B/C
    (2, 128, 2, 64, 1, 128, 128),        # single chunk
    (1, 100, 4, 32, 1, 64, 32),          # padding
])
def test_ssd_scan_sweep(b, S, H, P, G, N, chunk, dtype):
    x = _rand((b, S, H, P), dtype, 10)
    dt = jax.nn.softplus(_rand((b, S, H), jnp.float32, 11))
    A = -jnp.exp(_rand((H,), jnp.float32, 12, scale=0.5))
    B = _rand((b, S, G, N), dtype, 13, scale=0.3)
    C = _rand((b, S, G, N), dtype, 14, scale=0.3)
    y, sf = ssd_scan(x, dt, A, B, C, chunk=chunk)
    yr, sr = ssd_scan_ref(x, dt, A, B, C)
    tol = 1e-3 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [
    jnp.float32,
    # bf16 sweeps double kernel-test wall time; fast lane keeps f32
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("E,D,F,bm,sizes", [
    (4, 64, 128, 32, (64, 32, 96, 32)),
    (2, 128, 256, 64, (128, 64)),
    (8, 32, 64, 16, (16,) * 8),
    (3, 96, 96, 32, (0, 64, 32)),        # empty group
])
def test_grouped_matmul_sweep(E, D, F, bm, sizes, dtype):
    T = sum(sizes) + bm                   # tail rows owned by nobody
    x = _rand((T, D), dtype, 15)
    x = x.at[sum(sizes):].set(0)
    w = _rand((E, D, F), dtype, 16, scale=0.1)
    gs = jnp.asarray(sizes, jnp.int32)
    y = grouped_matmul(x, w, gs, block_m=bm, block_n=32)
    yr = grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [
    jnp.float32,
    # bf16 sweeps double kernel-test wall time; fast lane keeps f32
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("shape", [(4, 64, 96), (2, 256, 960), (8, 128)])
@pytest.mark.parametrize("with_residual", [False, True])
def test_rmsnorm_sweep(shape, dtype, with_residual):
    x = _rand(shape, dtype, 17)
    res = _rand(shape, dtype, 18) if with_residual else None
    sc = _rand((shape[-1],), jnp.float32, 19, scale=0.1)
    o, r = rmsnorm_fused(x, sc, res)
    orf, rrf = rmsnorm_ref(x, sc, residual=res)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(r, np.float32),
                               np.asarray(rrf, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_noncausal_unaligned():
    """Non-causal with T not block-aligned: padded kv rows must get zero
    softmax mass (regression for the t_total plumbing)."""
    q = _rand((1, 48, 4, 32), jnp.float32, 20)
    k = _rand((1, 100, 2, 32), jnp.float32, 21)
    v = _rand((1, 100, 2, 32), jnp.float32, 22)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=2e-2)
