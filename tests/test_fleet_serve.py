"""Fleet serve pool: per-request leases, requeue-on-pilot-failure,
exactly-once completion, and the engine's per-request drain/cancel hooks.

The dispatcher unit tests are pure threading (fast lane); everything that
builds a model engine or spawns pilots carries @pytest.mark.slow.
"""

from __future__ import annotations

import time

import numpy as np
import pytest

from repro.serving.dispatch import FleetDispatcher, get_pool


def _entries(n, plen=3):
    return [{"rid": i, "prompt": list(range(1, 1 + plen)),
             "max_new_tokens": 4} for i in range(n)]


# ---------------------------------------------------------------------------
# dispatcher contracts (fast lane)
# ---------------------------------------------------------------------------

def test_pool_registry_and_close():
    pool = FleetDispatcher(name="test-pool-reg")
    assert get_pool("test-pool-reg") is pool
    pool.close()
    assert get_pool("test-pool-reg") is None


def test_requeue_on_silent_server_death():
    """A server that stops renewing (died) loses its leases to the repo's
    reaper; a survivor parked in fetch is handed the requeued requests and
    completes every one exactly once."""
    pool = FleetDispatcher(lease_ttl=0.15)
    try:
        pool.submit_trace(_entries(3))
        got_a = pool.fetch("A", max_n=2)
        assert [e["rid"] for e in got_a] == [0, 1]
        assert pool.complete("A", 0, [7, 8])
        # A dies silently.  B picks up the remainder, including A's
        # expired rid 1, without anyone polling.
        done = set()
        deadline = time.monotonic() + 10.0
        while len(done) < 2 and time.monotonic() < deadline:
            for e in pool.fetch("B", max_n=2, timeout=5.0):
                pool.complete("B", e["rid"], [e["rid"]])
                done.add(e["rid"])
        assert done == {1, 2}
        assert pool.wait_all(timeout=5.0)
        s = pool.stats()
        assert s["completed"] == 3 and s["replays"] >= 1
        assert pool.records()[1].server == "B"      # replayed on the survivor
    finally:
        pool.close()


def test_first_completion_wins_drops_duplicates():
    """The original server racing a replayed copy: one accepted result, one
    counted duplicate — never two completions for a request id."""
    pool = FleetDispatcher(lease_ttl=0.1)
    try:
        pool.submit_trace(_entries(1))
        (a,) = pool.fetch("A", max_n=1)
        time.sleep(0.3)                       # A's lease expires
        (b,) = pool.fetch("B", max_n=1, timeout=5.0)
        assert b["rid"] == a["rid"] == 0 and b["attempt"] == 2
        assert pool.complete("B", 0, [1, 2, 3]) is True
        assert pool.complete("A", 0, [1, 2, 3]) is False
        assert pool.results() == {0: [1, 2, 3]}
        assert pool.records()[0].server == "B"
        assert pool.stats()["duplicates"] == 1
    finally:
        pool.close()


def test_renew_piggybacks_progress_and_reports_lost_leases():
    pool = FleetDispatcher(lease_ttl=0.1)
    try:
        pool.submit_trace(_entries(1))
        pool.fetch("A", max_n=1)
        assert pool.renew("A", {0: 2}) == []          # still held
        assert pool.records()[0].progress == 2
        time.sleep(0.3)                               # expire
        pool.fetch("B", max_n=1, timeout=5.0)         # re-leased elsewhere
        assert pool.renew("A", {0: 5}) == [0]         # A must cancel rid 0
        assert pool.stats()["lost_leases"] == 1
        assert pool.renew("B", {0: 1}) == []
    finally:
        pool.close()


def test_release_requeues_immediately():
    """A graceful hand-back does not wait out the lease TTL."""
    pool = FleetDispatcher(lease_ttl=60.0)            # TTL can't be the path
    try:
        pool.submit_trace(_entries(1))
        pool.fetch("A", max_n=1)
        assert pool.fetch("B", max_n=1) == []         # leased away
        pool.release("A", [0])
        got = pool.fetch("B", max_n=1, timeout=5.0)
        assert [e["rid"] for e in got] == [0]
    finally:
        pool.close()


def test_reject_settles_as_failed_after_max_attempts():
    """An unservable request (e.g. prompt beyond every engine's max_len)
    must not ping-pong forever — it retries max_attempts times and then
    settles as failed, so wait_all still returns."""
    pool = FleetDispatcher(lease_ttl=60.0, max_attempts=2)
    try:
        pool.submit_trace(_entries(1))
        for _ in range(2):
            (e,) = pool.fetch("A", max_n=1, timeout=5.0)
            pool.reject("A", e["rid"])
        assert pool.fetch("A", max_n=1) == []
        assert pool.wait_all(timeout=5.0)
        s = pool.stats()
        assert s["failed"] == 1 and s["completed"] == 0
    finally:
        pool.close()


def test_wait_servers_barrier():
    pool = FleetDispatcher()
    try:
        assert pool.wait_servers(1, timeout=0.05) is False
        pool.announce("A")
        assert pool.wait_servers(1, timeout=5.0)
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# engine per-request drain/cancel (model-level, slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_cancel_returns_request_and_frees_blocks():
    import jax
    from repro.configs.base import get_smoke_config
    from repro.models.api import build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("smollm-360m")
    params = build_model(cfg).init(jax.random.key(0))

    def req(rid, plen, mnt):
        rng = np.random.default_rng(rid)
        return Request(rid=rid, max_new_tokens=mnt,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           size=plen).astype(np.int32))

    solo = ServeEngine(cfg, params, slots=2, max_len=64)
    solo.submit(req(1, 9, 8))
    solo.run()
    solo_tokens = tuple(solo.done[1].tokens)

    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    free0 = eng.allocator.available_blocks
    eng.submit(req(0, 7, 30))
    eng.submit(req(1, 9, 8))
    eng.submit(req(2, 5, 4))                      # queued behind the slots
    for _ in range(3):
        eng.step()
    # cancel a QUEUED request: no slot was touched
    assert eng.cancel(2).rid == 2
    # cancel a LIVE slot mid-decode: request comes back with its tokens,
    # its blocks return to the pool, and the neighbor's stream is untouched
    got = eng.cancel(0)
    assert got is not None and len(got.tokens) >= 1
    assert eng.cancel(0) is None                  # already gone
    eng.run()
    assert tuple(eng.done[1].tokens) == solo_tokens
    assert 1 in eng.done and 0 not in eng.done and 2 not in eng.done
    assert eng.allocator.available_blocks == free0   # every block returned


@pytest.mark.slow
def test_engine_drain_requests_exports_everything():
    import jax
    from repro.configs.base import get_smoke_config
    from repro.models.api import build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("smollm-360m")
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    for rid in range(3):
        rng = np.random.default_rng(rid)
        eng.submit(Request(rid=rid, max_new_tokens=20,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               size=6).astype(np.int32)))
    for _ in range(2):
        eng.step()
    out = eng.drain_requests()
    assert sorted(r.rid for r in out) == [0, 1, 2]
    assert not eng._live and not eng.queue and not eng._jobs
    assert all(m.rid == -1 for m in eng.slot_meta)
    assert eng.allocator.available_blocks == eng.allocator.capacity_blocks


# ---------------------------------------------------------------------------
# the headline scenario: kill a serving pilot mid-trace (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_requeue_on_pilot_failure():
    """Kill 1 of 3 serving pilots mid-trace: every request completes exactly
    once on a survivor, and the completed tokens match a no-failure run
    bitwise (replay-from-prompt over identical weights is deterministic)."""
    from repro.core.images import ExecutableRegistry
    from repro.launch.serve import serve_fleet

    registry = ExecutableRegistry()
    ok = serve_fleet("smollm-360m", 10, 3, slots=2, max_len=64,
                     fail_at=None, lease_ttl=0.5, registry=registry)
    failed = serve_fleet("smollm-360m", 10, 3, slots=2, max_len=64,
                         fail_at=2, lease_ttl=0.5, registry=registry)
    assert ok["completed"] == 10 and ok["replays"] == 0
    assert failed["completed"] == 10
    assert len(failed["failed_pilots"]) == 1
    # exactly once: 10 accepted results, every duplicate dropped visibly
    assert sorted(failed["results"]) == list(range(10))
    assert failed["results"] == ok["results"]
    assert failed["replays"] >= 1            # the dead pilot's in-flight work


@pytest.mark.slow
def test_fleet_scale_up_joins_mid_trace():
    """A pilot provisioned AFTER serving started leases into the same pool
    and completes part of the trace — late-binding capacity growth without
    touching running requests."""
    from repro.core.cluster import ClusterSim
    from repro.core.images import PayloadImage
    from repro.core.pilot import PilotConfig
    from repro.launch.serve import make_trace
    from repro.configs.base import get_smoke_config

    cfg = get_smoke_config("smollm-360m")
    sim = ClusterSim()
    pool = FleetDispatcher(lease_ttl=1.0)
    try:
        img = PayloadImage("smollm-360m", "smoke", "serve")
        fleet = sim.spawn_fleet(1, PilotConfig(max_payloads=2, idle_grace=0.5))
        fleet.submit_servers(img, pool.name, n=1,
                             spec={"slots": 2, "max_len": 64})
        assert pool.wait_servers(1, timeout=300.0)
        trace = make_trace(cfg.vocab_size, 16, max_len=64, seed=1)
        pool.submit_trace(trace[:4])
        assert pool.wait_completed(2, timeout=120.0)
        fleet.scale_up(1)
        fleet.submit_servers(img, pool.name, n=1,
                             spec={"slots": 2, "max_len": 64})
        # feed the bulk of the trace only once the joiner is up, so both
        # servers demonstrably hold leases side by side
        assert pool.wait_servers(2, timeout=300.0)
        pool.submit_trace(trace[4:])
        pool.seal()
        assert pool.wait_all(timeout=300.0)
        stats = pool.stats()
        assert stats["completed"] == 16
        assert stats["distinct_servers"] == 2     # the joiner did real work
    finally:
        pool.close()
        fleet.drain_all()
        fleet.join_all(30.0)
