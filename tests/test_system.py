"""Import-everything sanity + public API surface."""


def test_imports():
    import repro.configs.base            # noqa: F401
    import repro.core.arena              # noqa: F401
    import repro.core.cluster            # noqa: F401
    import repro.core.images             # noqa: F401
    import repro.core.latebind           # noqa: F401
    import repro.core.monitor            # noqa: F401
    import repro.core.pilot              # noqa: F401
    import repro.core.proctable          # noqa: F401
    import repro.core.taskrepo           # noqa: F401
    import repro.core.wrapper            # noqa: F401
    import repro.ckpt.checkpoint         # noqa: F401
    import repro.data.synthetic          # noqa: F401
    import repro.launch.hlo_stats        # noqa: F401
    import repro.launch.mesh             # noqa: F401
    import repro.launch.specs            # noqa: F401
    import repro.launch.steps            # noqa: F401
    import repro.models.api              # noqa: F401
    import repro.optim.adamw             # noqa: F401
    import repro.runtime.compression     # noqa: F401
    import repro.runtime.elastic         # noqa: F401
    import repro.runtime.mesh            # noqa: F401
    import repro.runtime.sharding        # noqa: F401
    import repro.serving.engine          # noqa: F401


def test_arch_registry_complete():
    from repro.configs.base import list_archs
    assert list_archs() == (
        "gemma-2b", "granite-moe-3b-a800m", "jamba-v0.1-52b",
        "llava-next-mistral-7b", "mamba2-370m", "minicpm3-4b",
        "mixtral-8x7b", "smollm-360m", "starcoder2-3b", "whisper-small")


def test_every_arch_has_smoke_config():
    from repro.configs.base import get_smoke_config, list_archs
    for a in list_archs():
        cfg = get_smoke_config(a)
        assert cfg.num_layers <= 8, (a, "smoke config must be reduced")
        assert cfg.vocab_size <= 4096
