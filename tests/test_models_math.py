"""Numerics of the model substrate: attention impls, fused loss, SSD scan,
MoE dispatch — including hypothesis property tests on the invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoESpec, SSMSpec, get_smoke_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (lm_logits, softmax_cross_entropy,
                                 softmax_cross_entropy_fused)

KEY = jax.random.key(7)


def _r(shape, k, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(jax.random.fold_in(KEY, k), shape) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# attention implementations agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,K,Dh,window,chunk", [
    (128, 4, 2, 64, None, 32),
    (96, 6, 3, 32, None, 64),
    (128, 4, 4, 64, 48, 32),
])
def test_chunked_attention_matches_dense(S, H, K, Dh, window, chunk):
    q, k, v = (_r((2, S, H, Dh), i) for i in range(3))
    k = _r((2, S, K, Dh), 4)
    v = _r((2, S, K, Dh), 5)
    out = attn.chunked_attention(q, k, v, causal=True, window=window,
                                 chunk=chunk)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=2e-2)


@pytest.mark.parametrize("S,window", [(128, None), (256, None), (256, 64)])
def test_causal_blocked_matches_chunked(S, window):
    q = _r((1, S, 4, 64), 6)
    k = _r((1, S, 2, 64), 7)
    v = _r((1, S, 2, 64), 8)
    a = attn.causal_blocked_attention(q, k, v, window=window, chunk=32,
                                      block_q=64)
    b = attn.chunked_attention(q, k, v, causal=True, window=window, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-2, atol=2e-2)


def test_decode_attend_matches_dense_row():
    """decode_attend == last row of full attention with same cache."""
    B, T, H, K, Dh = 2, 64, 4, 2, 32
    q = _r((B, 1, H, Dh), 9)
    kc = _r((B, T, K, Dh), 10)
    vc = _r((B, T, K, Dh), 11)
    out = attn.decode_attend(q, kc, vc, jnp.int32(T))
    ref = attention_ref(q, kc, vc, causal=True, q_offset=T - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# fused CE loss (hypothesis property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(2, 130),
    v=st.integers(5, 120),
    chunk=st.sampled_from([16, 32, 64]),
    softcap=st.sampled_from([None, 30.0]),
)
def test_fused_ce_equals_dense(b, s, v, chunk, softcap):
    d = 16
    h = _r((b, s, d), 20, dtype=jnp.float32)
    head = _r((d, v), 21, scale=0.2)
    t = jax.random.randint(jax.random.fold_in(KEY, 22), (b, s), 0, v)
    dense = softmax_cross_entropy(lm_logits(h, head, softcap), t)
    fused = softmax_cross_entropy_fused(h, head, t, softcap=softcap,
                                        chunk=chunk)
    np.testing.assert_allclose(float(dense), float(fused), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(40, 90), frac=st.floats(0.1, 0.9))
def test_fused_ce_mask_semantics(s, frac):
    b, d, v = 2, 16, 50
    h = _r((b, s, d), 23)
    head = _r((d, v), 24, scale=0.2)
    t = jax.random.randint(jax.random.fold_in(KEY, 25), (b, s), 0, v)
    mask = (jax.random.uniform(jax.random.fold_in(KEY, 26), (b, s))
            < frac).astype(jnp.float32)
    dense = softmax_cross_entropy(lm_logits(h, head, None), t, mask)
    fused = softmax_cross_entropy_fused(h, head, t, mask=mask, chunk=32)
    np.testing.assert_allclose(float(dense), float(fused), rtol=1e-5,
                               atol=1e-5)


def test_fused_ce_gradients_match():
    b, s, d, v = 2, 96, 16, 64
    h = _r((b, s, d), 27)
    head = _r((d, v), 28, scale=0.2)
    t = jax.random.randint(jax.random.fold_in(KEY, 29), (b, s), 0, v)
    g1 = jax.grad(lambda hh: softmax_cross_entropy(
        lm_logits(hh, head, None), t))(h)
    g2 = jax.grad(lambda hh: softmax_cross_entropy_fused(
        hh, head, t, chunk=32))(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-6)


# ---------------------------------------------------------------------------
# SSD scan invariants
# ---------------------------------------------------------------------------

def _ssm_cfg(**kw):
    base = get_smoke_config("mamba2-370m")
    return dataclasses.replace(base, ssm=SSMSpec(**{**dict(
        state_dim=base.ssm.state_dim, head_dim=base.ssm.head_dim,
        expand=base.ssm.expand, conv_width=base.ssm.conv_width,
        chunk_size=base.ssm.chunk_size, n_groups=base.ssm.n_groups), **kw}))


@settings(max_examples=8, deadline=None)
@given(chunk_a=st.sampled_from([16, 32, 64]),
       chunk_b=st.sampled_from([16, 32, 128]),
       s=st.integers(33, 130))
def test_ssd_chunk_size_invariance(chunk_a, chunk_b, s):
    """The chunked SSD evaluation must not depend on the chunk size."""
    b, H, P, N = 1, 2, 32, 64
    x = _r((b, s, H, P), 30)
    dt = jax.nn.softplus(_r((b, s, H), 31))
    A = -jnp.exp(_r((H,), 32, scale=0.3))
    B = _r((b, s, 1, N), 33, scale=0.3)
    C = _r((b, s, 1, N), 34, scale=0.3)
    ya, sa = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk_a)
    yb, sb = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=2e-4, atol=2e-4)


def test_ssm_prefill_state_matches_decode():
    """Running prefill over S tokens then decoding one more must equal a
    prefill over S+1 tokens (state-carry correctness)."""
    cfg = get_smoke_config("mamba2-370m")
    p = ssm_mod.init_ssm(jax.random.fold_in(KEY, 35), cfg)
    S = 24
    x = _r((1, S + 1, cfg.d_model), 36, scale=0.5, dtype=jnp.bfloat16)
    out_full, _ = ssm_mod.ssm_forward_with_cache(x, p, cfg)
    _, cache = ssm_mod.ssm_forward_with_cache(x[:, :S], p, cfg)
    out_step, _ = ssm_mod.ssm_decode(x[:, S:S + 1], p, cfg, cache)
    np.testing.assert_allclose(
        np.asarray(out_step[:, 0], np.float32),
        np.asarray(out_full[:, S], np.float32), rtol=0.1, atol=0.1)


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

def test_moe_capacity_dispatch_matches_dense():
    """With ample capacity, bucketed dispatch == dense all-experts gating."""
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    p = moe_mod.init_moe(jax.random.fold_in(KEY, 37), cfg)
    x = _r((2, 16, cfg.d_model), 38, scale=0.5, dtype=jnp.bfloat16)
    y_bucket, _ = moe_mod.apply_moe(x, p, cfg)
    y_dense, _ = moe_mod.apply_moe_dense(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_bucket, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=0.1, atol=0.05)


def test_moe_aux_loss_uniform_router_is_one():
    """Switch aux loss == router_aux_weight when routing is uniform."""
    cfg = get_smoke_config("granite-moe-3b-a800m")
    E = cfg.moe.num_experts
    p = moe_mod.init_moe(jax.random.fold_in(KEY, 39), cfg)
    p = {**p, "router": jnp.zeros_like(p["router"])}       # uniform probs
    x = _r((1, 64, cfg.d_model), 40, dtype=jnp.bfloat16)
    _, aux = moe_mod.apply_moe(x, p, cfg)
    # me = 1/E; ce sums to k tokens spread evenly -> aux = w * E * sum(me*ce/k)
    np.testing.assert_allclose(float(aux), cfg.moe.router_aux_weight,
                               rtol=0.15)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(8, 64))
def test_moe_capacity_bounds(s):
    cfg = get_smoke_config("mixtral-8x7b")
    c = moe_mod._capacity(cfg, s)
    assert 1 <= c <= s
    assert c % 8 == 0 or c == s
