"""Chaos layer + gray-failure hardening contracts.

Everything here is the fast lane: the dispatcher's detection layers
(progress watchdog, hedged re-dispatch, backoff requeue, blast-radius
quarantine) are pure threading over the request repo, and the chaos
controller is driven against stub sims/fleets.  The end-to-end drills
(real engines, real pilots) live in ``benchmarks/bench_chaos.py``.
"""

from __future__ import annotations

import random
import threading
import time

from repro.core import chaos
from repro.core.autoscaler import AutoscalePolicy, FleetAutoscaler
from repro.core.chaos import ChaosController, FaultPlan, FaultSpec
from repro.core.taskrepo import BackoffPolicy, TaskRepo, TaskResult
from repro.serving.dispatch import FleetDispatcher, RobustnessPolicy

NOOP_IMG = "serve-request"            # repo tasks here never run a payload


def _wait(pred, timeout=5.0, dt=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(dt)
    return pred()


# ---------------------------------------------------------------------------
# backoff requeue (the immediate-requeue hot-loop regression)
# ---------------------------------------------------------------------------

def test_backoff_delay_is_deterministic_exponential_and_capped():
    p = BackoffPolicy(base=0.1, cap=0.4, jitter=0.5)
    d1, d2, d3, d6 = (p.delay(7, n) for n in (1, 2, 3, 6))
    assert d1 == p.delay(7, 1)                 # deterministic, not random
    assert 0.05 <= d1 <= 0.15                  # base +/- jitter
    assert d2 > d1 and d3 > d2                 # exponential growth
    assert d6 <= 0.4 * 1.5                     # capped (+ jitter headroom)
    assert BackoffPolicy(base=0.0).delay(7, 5) == 0.0


def test_failure_requeue_backs_off_no_hot_loop():
    """Regression for the hot loop: a payload that crashes instantly used
    to bounce queue->lease->release(failed)->queue at match cadence.  With
    backoff, three rapid failures may not produce three sub-interval
    redispatches — and a HEALTHY task keeps matching immediately the whole
    time (the deferred heap never blocks the open queue)."""
    repo = TaskRepo(lease_ttl=60.0,
                    backoff=BackoffPolicy(base=0.2, cap=1.0, jitter=0.0))
    crash_tid = repo.submit(NOOP_IMG, payload_spec={"which": "crasher"})
    redispatches = 0
    t0 = time.monotonic()
    for _ in range(3):
        t = repo.match({"pilot_id": "p1"})
        if t is None or t.task_id != crash_tid:
            break
        redispatches += 1
        repo.release(t, failed=True, pilot_id="p1")
    # without backoff this loop spins 3 redispatches in microseconds;
    # with base=0.2 the second comes no earlier than 0.2s
    assert not (redispatches >= 3 and time.monotonic() - t0 < 0.2)
    # a healthy task submitted NOW matches immediately, crasher deferred
    ok_tid = repo.submit(NOOP_IMG, payload_spec={"which": "ok"})
    t = repo.match({"pilot_id": "p2"})
    assert t is not None and t.task_id == ok_tid
    # the crasher becomes eligible again on its own (defer timer, no kick)
    got = repo.match_wait({"pilot_id": "p3"}, timeout=5.0)
    assert got is not None and got.task_id == crash_tid
    s = repo.stats()
    assert s["leased"] == 2


def test_deferred_release_honored_by_match_wait():
    repo = TaskRepo(lease_ttl=60.0, backoff=BackoffPolicy(base=0.0))
    repo.submit(NOOP_IMG)
    t = repo.match({"pilot_id": "A"})
    t_defer = time.monotonic()
    repo.release(t, pilot_id="A", defer_s=0.25)
    assert repo.match({"pilot_id": "B"}) is None      # not eligible yet
    got = repo.match_wait({"pilot_id": "B"}, timeout=5.0)
    assert got is not None and got.task_id == t.task_id
    assert time.monotonic() - t_defer >= 0.2


# ---------------------------------------------------------------------------
# progress watchdog (stall revoke + sick bench)
# ---------------------------------------------------------------------------

def _entries(n):
    return [{"rid": i, "prompt": [1, 2, 3], "max_new_tokens": 4}
            for i in range(n)]


def test_stall_watchdog_revokes_and_benches_server():
    """A request renewing on schedule but FROZEN past stall_deadline is
    revoked; the stalled server is benched (fetch returns nothing) while a
    survivor picks the request up immediately (no backoff: the request is
    healthy, its server is not)."""
    pol = RobustnessPolicy(stall_deadline=0.15, sick_cooldown=0.6,
                           hedging=False, quarantine_after=0,
                           backoff=BackoffPolicy(base=0.0))
    pool = FleetDispatcher(lease_ttl=5.0, policy=pol)
    try:
        pool.submit_trace(_entries(1))
        (e,) = pool.fetch("A", max_n=1)
        assert pool.renew("A", {0: 2}) == []          # progressing: fine
        time.sleep(0.2)
        assert pool.renew("A", {0: 2}) == [0]         # frozen: revoked
        s = pool.stats()
        assert s["stalls_revoked"] == 1
        assert pool.fetch("A", max_n=1) == []         # benched
        (e2,) = pool.fetch("B", max_n=1, timeout=5.0)  # survivor replays
        assert e2["rid"] == 0 and e2["attempt"] == 2
        assert pool.complete("B", 0, [9, 9])
        assert pool.pool_pressure()["sick_servers"] == 1
        assert _wait(lambda: pool.fetch("A", max_n=1) == [], timeout=0.1)
        time.sleep(0.6)                               # cooldown passes
        assert pool.pool_pressure()["sick_servers"] == 0
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# hedged re-dispatch
# ---------------------------------------------------------------------------

def test_hedge_rescues_straggler_first_completion_wins():
    """A leased request past the straggler budget gets a duplicate with
    anti-affinity; the fast copy wins, the straggler's completion is a
    counted duplicate, and the pool settles exactly once."""
    pol = RobustnessPolicy(stall_deadline=0.0, hedging=True,
                           hedge_min_s=0.1, hedge_min_samples=99,
                           watchdog_interval=0.02, max_hedges=1,
                           quarantine_after=0,
                           backoff=BackoffPolicy(base=0.0))
    pool = FleetDispatcher(lease_ttl=2.0, policy=pol)
    try:
        pool.submit_trace(_entries(1))
        (e,) = pool.fetch("A", max_n=1)
        assert _wait(lambda: pool.stats()["hedges"] >= 1)
        # anti-affinity: the straggler itself can NOT lease its own hedge
        assert pool.fetch("A", max_n=1) == []
        (h,) = pool.fetch("B", max_n=1, timeout=5.0)
        assert h["rid"] == 0
        # both copies race; the original holder is still live pool-side
        assert pool.renew("A", {0: 1}) == []
        assert pool.complete("B", 0, [5, 6]) is True
        assert pool.complete("A", 0, [5, 6]) is False   # loser: duplicate
        assert pool.renew("A", {0: 2}) == [0]           # tombstoned: cancel
        assert pool.results() == {0: [5, 6]}
        s = pool.stats()
        assert s["completed"] == 1 and s["hedges"] == 1
        assert s["duplicates"] == 1
        assert pool.wait_all(timeout=5.0)
        rs = pool.repo.stats()
        assert rs["queued"] == 0 and rs["leased"] == 0   # nothing stranded
    finally:
        pool.close()


def test_hedge_requires_a_freshly_renewing_holder():
    """Hedging is for LIVE stragglers.  A holder that stopped renewing is
    dead or partitioned — the lease reaper's requeue (with blame
    accounting) handles it; racing a hedge into the gap would burn a slot
    and, for a poison request, kill a third pilot."""
    pol = RobustnessPolicy(stall_deadline=0.0, hedging=True,
                           hedge_min_s=0.3, hedge_min_samples=99,
                           watchdog_interval=0.02, max_hedges=1,
                           quarantine_after=0,
                           backoff=BackoffPolicy(base=0.0))
    pool = FleetDispatcher(lease_ttl=0.3, policy=pol)   # fresh horizon .15s
    try:
        pool.submit_trace(_entries(1))
        pool.fetch("A", max_n=1)
        time.sleep(0.45)          # budget crossed only after A went stale
        assert pool.stats()["hedges"] == 0
        # the reaper requeued it instead; a survivor completes normally
        (e,) = pool.fetch("B", max_n=1, timeout=5.0)
        assert e["attempt"] == 2
        assert pool.complete("B", 0, [1])
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# blast-radius quarantine + canary placement
# ---------------------------------------------------------------------------

def test_quarantine_poison_after_two_deaths_without_false_positives():
    """Two requests die with a pilot: the one that had produced tokens is
    collateral (no strike); the zero-progress one becomes a suspect,
    canaries SOLO on the next server, and is quarantined when that canary
    dies too — while the collateral request completes fine elsewhere."""
    pol = RobustnessPolicy(stall_deadline=0.0, hedging=False,
                           quarantine_after=2,
                           backoff=BackoffPolicy(base=0.0))
    pool = FleetDispatcher(lease_ttl=0.15, policy=pol)
    try:
        pool.submit_trace(_entries(2))
        got = pool.fetch("A", max_n=2)
        assert [e["rid"] for e in got] == [0, 1]
        pool.renew("A", {0: 3, 1: 0})      # rid 0 progressed, rid 1 frozen
        # A dies silently; the reaper strikes ONLY the zero-progress rid
        assert _wait(lambda: pool.records()[1].implicated == {"A"})
        assert pool.records()[0].implicated == set()

        # canary placement: B currently holds a zero-progress request, so
        # the suspect must not land there yet
        (e0,) = pool.fetch("B", max_n=2, timeout=5.0)
        assert e0["rid"] == 0               # the healthy replay, not rid 1
        pool.renew("B", {0: 1})             # B's held work has progressed
        (e1,) = pool.fetch("B", max_n=1, timeout=5.0)
        assert e1["rid"] == 1               # now eligible as a canary
        # solo-canary: while holding a suspect, B fetches nothing else
        pool.submit(_entries(3)[2])
        assert pool.fetch("B", max_n=1) == []

        # B dies too: second distinct pilot death with zero progress ->
        # quarantined; B's progressed rid 0 is again collateral
        assert _wait(lambda: pool.records()[1].quarantined)
        rec = pool.records()[1]
        assert rec.failed and "quarantined" in rec.fail_reason
        assert pool.stats()["quarantined"] == 1

        # the collateral + late request complete on a healthy server
        done = set()
        while len(done) < 2:
            for e in pool.fetch("C", max_n=2, timeout=5.0):
                pool.complete("C", e["rid"], [7])
                done.add(e["rid"])
        assert done == {0, 2}
        pool.seal()
        assert pool.wait_all(timeout=5.0)
        s = pool.stats()
        assert s["completed"] == 2 and s["failed"] == 1
    finally:
        pool.close()


def test_suspect_exonerated_on_first_token():
    """An innocent co-fetched with an undetected poison gets implicated by
    the first death — but the moment it produces a token on its canary it
    is exonerated (the poison NEVER progresses), shedding the canary tax
    and the strike history."""
    pol = RobustnessPolicy(stall_deadline=0.0, hedging=False,
                           quarantine_after=2,
                           backoff=BackoffPolicy(base=0.0))
    pool = FleetDispatcher(lease_ttl=0.15, policy=pol)
    try:
        pool.submit_trace(_entries(1))
        pool.fetch("A", max_n=1)
        assert _wait(lambda: pool.records()[0].implicated == {"A"})
        (e,) = pool.fetch("B", max_n=1, timeout=5.0)
        assert pool.renew("B", {0: 1}) == []
        assert pool.records()[0].implicated == set()
        assert pool.complete("B", 0, [4])
        assert pool.wait_all(timeout=5.0)
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# requeue/hedge/cancel/complete under racing servers (stress)
# ---------------------------------------------------------------------------

def test_stress_racing_servers_settle_exactly_once():
    """Servers that complete, release, and silently die while hedging and
    the lease reaper churn underneath: every request settles exactly once
    with the right tokens, no lease is left held, and the repo drains to
    zero queued/leased."""
    n = 40
    pol = RobustnessPolicy(stall_deadline=0.0, hedging=True,
                           hedge_min_s=0.15, hedge_min_samples=4,
                           hedge_percentile=50.0, hedge_factor=3.0,
                           watchdog_interval=0.02, max_hedges=2,
                           quarantine_after=0,
                           backoff=BackoffPolicy(base=0.01, cap=0.1))
    pool = FleetDispatcher(lease_ttl=0.12, max_attempts=64, policy=pol)
    accepted: dict[int, int] = {}
    # lint: allow[bare-lock] -- test-harness accounting lock; raw so the stress race's lock graph stays product-locks-only
    acc_lock = threading.Lock()

    def tokens_for(rid):
        return [rid, rid + 1, rid + 2]

    def server(name, seed):
        rng = random.Random(seed)
        while not pool.finished():
            got = pool.fetch(name, max_n=2, timeout=0.05)
            if not got:
                continue
            held = {}
            for e in got:
                roll = rng.random()
                if roll < 0.45:                      # fast completion
                    if pool.complete(name, e["rid"], tokens_for(e["rid"])):
                        with acc_lock:
                            accepted[e["rid"]] = accepted.get(e["rid"], 0) + 1
                elif roll < 0.65:                    # graceful hand-back
                    pool.release(name, [e["rid"]])
                elif roll < 0.8:                     # silent death: forget
                    pass
                else:                                # slow-ish holder
                    held[e["rid"]] = 0
            for _ in range(rng.randrange(1, 4)):
                if not held:
                    break
                time.sleep(0.02)
                for rid in list(held):
                    held[rid] += 1
                lost = pool.renew(name, dict(held))
                for rid in lost:
                    held.pop(rid, None)
            for rid in list(held):
                if pool.complete(name, rid, tokens_for(rid)):
                    with acc_lock:
                        accepted[rid] = accepted.get(rid, 0) + 1

    pool.submit_trace(_entries(n))
    pool.seal()
    threads = [threading.Thread(target=server, args=(f"s{i}", 1000 + i),
                                daemon=True) for i in range(6)]
    try:
        for t in threads:
            t.start()
        assert pool.wait_all(timeout=60.0), pool.stats()
        for t in threads:
            t.join(timeout=10.0)
        s = pool.stats()
        assert s["completed"] == n and s["failed"] == 0
        results = pool.results()
        for rid in range(n):
            assert results[rid] == tokens_for(rid)
        with acc_lock:
            assert all(v == 1 for v in accepted.values())   # exactly once
        rs = pool.repo.stats()
        assert rs["queued"] == 0 and rs["leased"] == 0
        assert pool.lease_holders() == {}                   # no held lease
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# chaos controller + sites (stub sim)
# ---------------------------------------------------------------------------

class _StubPilot:
    def __init__(self, pid):
        self.pilot_id = pid


class _StubSim:
    def __init__(self, pids):
        self.pids = list(pids)
        self.failed: list[str] = []

    def live_pilots(self):
        return [_StubPilot(p) for p in self.pids if p not in self.failed]

    def fail_pilot(self, pid):
        self.failed.append(pid)
        return True


def test_chaos_site_stamps_expire_on_their_own():
    sim = _StubSim(["p1"])
    ctl = ChaosController(sim, plan=FaultPlan())
    with ctl:
        s = chaos.site("p1")
        assert s is not None
        assert not s.stalled() and s.slow_factor() == 1.0
        assert not s.partitioned() and not s.drop_heartbeat()
        now = time.monotonic()
        s.stall_until = now + 0.1
        s.slow_by, s.slow_until = 8.0, now + 0.1
        s.cut_until = now + 0.1
        s.drop_rate, s.flaky_until = 1.0, now + 0.1
        assert s.stalled() and s.slow_factor() == 8.0
        assert s.partitioned() and s.drop_heartbeat()
        time.sleep(0.12)                      # stamps clear themselves
        assert not s.stalled() and s.slow_factor() == 1.0
        assert not s.partitioned() and not s.drop_heartbeat()
    assert chaos.site("p1") is None           # uninstalled: hot path off


def test_controller_schedules_faults_and_poison_counts():
    sim = _StubSim(["p1", "p2"])
    plan = FaultPlan(faults=[
        FaultSpec(kind="crash", at_s=0.0, victim="p1"),
        FaultSpec(kind="slow", at_s=0.02, duration_s=5.0, factor=6.0,
                  victim="p2"),
    ], poison=True)
    ctl = ChaosController(sim, plan=plan)
    with ctl:
        assert _wait(lambda: len(ctl.log) >= 2)
        assert sim.failed == ["p1"]
        assert chaos.site("p2").slow_factor() == 6.0
        assert chaos.site("p2").poison_lethal()
        chaos.site("p2").trip_poison(7)
        assert sim.failed == ["p1", "p2"]
        assert ctl.poison_kills == {7: 1}
    st = ctl.stats()
    assert st["faults_applied"] == 3          # crash + slow + poison


def test_controller_picks_most_leases_victim_and_single_install():
    class _StubPool:
        def lease_holders(self):
            return {"p2": [1, 2, 3], "p1": [4]}

    sim = _StubSim(["p1", "p2"])
    ctl = ChaosController(sim, pool=_StubPool(),
                          plan=FaultPlan(faults=[FaultSpec(kind="crash")]))
    with ctl:
        assert _wait(lambda: sim.failed == ["p2"])   # most leases dies
        other = ChaosController(sim, plan=FaultPlan())
        try:
            other.start()
            raise AssertionError("double install must raise")
        except RuntimeError:
            pass
    assert chaos.site("p1") is None


# ---------------------------------------------------------------------------
# autoscaler: sick servers don't count as capacity
# ---------------------------------------------------------------------------

class _StubFleet:
    def __init__(self, n):
        self.n = n
        self.ups: list[int] = []

    def size(self):
        return self.n

    def draining(self):
        return 0

    def scale_up(self, n):
        self.n += n
        self.ups.append(n)
        return [object()] * n

    def scale_down(self, n):
        self.n -= n
        return []


def test_autoscaler_scales_up_around_sick_servers():
    """A stalled/quarantine-implicated server still holds its slice but
    serves nothing: with pool_sick_servers reported, effective capacity
    shrinks and the SAME demand that used to sit in the hysteresis band
    now forces a scale-up around the sick pilot."""
    p = AutoscalePolicy(min_pilots=0, max_pilots=8, slots_per_pilot=2,
                        high_water=1.25, low_water=0.5,
                        up_cooldown=0.0, down_cooldown=10.0,
                        down_stable_ticks=3)
    clk = [100.0]
    sig = {"demand": 8, "pool_sick_servers": 0}
    fleet = _StubFleet(4)
    a = FleetAutoscaler(fleet, None, policy=p,
                        signals_fn=lambda: dict(sig),
                        clock=lambda: clk[0])
    assert a.tick() is None           # util 8/(4*2) = 1.0: in band, hold
    clk[0] += 1.0
    sig["pool_sick_servers"] = 2      # same demand, two pilots black-holed
    d = a.tick()                      # util 8/(2*2) = 2.0: scale UP
    assert d is not None and d.direction == "up"
    assert fleet.n > 4


def test_pool_pressure_excludes_sick_server_telemetry():
    pool = FleetDispatcher(lease_ttl=1.0)
    try:
        pool.announce("A")
        pool.announce("B")
        pool.report_telemetry("A", {"kv_memory_utilization": 0.9,
                                    "tokens_per_step": 6.0,
                                    "blocked_admissions": 3})
        pool.report_telemetry("B", {"kv_memory_utilization": 0.2,
                                    "tokens_per_step": 2.0,
                                    "blocked_admissions": 1})
        pp = pool.pool_pressure()
        assert pp["sick_servers"] == 0
        assert pp["kv_memory_utilization"] == 0.9
        with pool._lock:
            pool._sick["A"] = time.monotonic() + 10.0
        pp = pool.pool_pressure()
        assert pp["sick_servers"] == 1
        # A's healthy-looking heartbeat no longer props up capacity...
        assert pp["kv_memory_utilization"] == 0.2
        assert pp["tokens_per_step"] == 2.0
        # ...but cumulative blocked counters still cover every server (the
        # autoscaler diffs per server; churn must not fabricate deltas)
        assert pp["blocked_admissions"] == 4
    finally:
        pool.close()
