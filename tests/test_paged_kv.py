"""Paged KV cache: allocator invariants, prefix-cache refcounts, kernel
vs oracle, paged-decode bitwise parity with the dense slab across every
arch family, copy-on-write safety of shared prefix blocks, chunked-prefill
interleaving, and the admit-length boundary.

Allocator/prefix/kernel/attention tests run in the fast lane; everything
that builds a full model engine carries @pytest.mark.slow.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config, list_archs
from repro.serving.blockpool import BlockAllocator, PrefixCache
from repro.serving.engine import (
    Request, ServeEngine, admit_buckets, admit_length, prefill_chunk_shapes)


def _params(cfg):
    from repro.models.api import build_model
    return build_model(cfg).init(jax.random.key(0))


def _req(rid, plen, max_new, vocab=512, seed=None):
    rng = np.random.default_rng(seed if seed is not None else rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
                   max_new_tokens=max_new)


# ---------------------------------------------------------------------------
# allocator invariants (fast lane)
# ---------------------------------------------------------------------------

def test_allocator_roundtrip_and_scratch_reserved():
    a = BlockAllocator(num_blocks=5, block_size=16)
    assert a.capacity_blocks == 4            # block 0 is scratch
    bids = [a.alloc() for _ in range(4)]
    assert 0 not in bids
    assert a.allocated_blocks == 4
    with pytest.raises(RuntimeError):
        a.alloc()                            # exhausted
    for b in bids:
        a.free(b)
    assert a.allocated_blocks == 0
    assert a.available_blocks == 4


def test_allocator_refcount_never_negative():
    a = BlockAllocator(num_blocks=4, block_size=16)
    b = a.alloc()
    a.free(b)
    with pytest.raises(RuntimeError):
        a.free(b)                            # double free
    # scratch block frees are no-ops, never underflow
    a.free(0)
    a.free(0)


def test_allocator_share_keeps_block_live():
    a = BlockAllocator(num_blocks=4, block_size=16)
    b = a.alloc()
    a.share(b)
    assert a.refcount(b) == 2
    a.free(b)
    assert a.allocated_blocks == 1           # still held by the share
    a.free(b)
    assert a.allocated_blocks == 0


# ---------------------------------------------------------------------------
# prefix cache (fast lane)
# ---------------------------------------------------------------------------

def test_prefix_chain_keys_prefix_property():
    toks = np.arange(64, dtype=np.int32)
    keys = PrefixCache.block_keys(toks, 16, 4)
    keys2 = PrefixCache.block_keys(toks.copy(), 16, 4)
    assert keys == keys2                     # deterministic
    diverged = toks.copy()
    diverged[20] = 999                       # inside block 1
    keys3 = PrefixCache.block_keys(diverged, 16, 4)
    assert keys3[0] == keys[0]               # block 0 unchanged
    assert keys3[1] != keys[1]               # chain breaks at the edit...
    assert keys3[2] != keys[2]               # ...and stays broken after


def test_prefix_cache_match_publish_evict():
    a = BlockAllocator(num_blocks=8, block_size=16)
    pc = PrefixCache(a)
    toks = np.arange(48, dtype=np.int32)
    keys = PrefixCache.block_keys(toks, 16, 3)
    owned = [a.alloc() for _ in range(3)]
    for k, b in zip(keys, owned):
        pc.publish(k, b)                     # cache takes one ref each
    assert all(a.refcount(b) == 2 for b in owned)
    for b in owned:                          # request evicted
        a.free(b)
    assert a.allocated_blocks == 3           # cache keeps them alive
    hit = pc.match(keys)
    assert hit == owned                      # longest-prefix, in order
    assert all(a.refcount(b) == 2 for b in owned)
    # a block referenced by a live request survives pressure eviction
    assert pc.evict_unreferenced(10) == 0
    for b in hit:
        a.free(b)
    assert pc.evict_unreferenced(2) == 2     # oldest-first, cache-only
    assert a.allocated_blocks == 1
    pc.clear()
    assert a.allocated_blocks == 0


def test_prefix_cache_partial_match_stops_at_divergence():
    a = BlockAllocator(num_blocks=8, block_size=16)
    pc = PrefixCache(a)
    toks = np.arange(48, dtype=np.int32)
    keys = PrefixCache.block_keys(toks, 16, 3)
    b0 = a.alloc()
    pc.publish(keys[0], b0)
    assert pc.match(keys) == [b0]            # only block 0 cached
    a.free(b0)


# ---------------------------------------------------------------------------
# admit_length boundary + bucket/chunk shape sets (fast lane)
# ---------------------------------------------------------------------------

def test_admit_length_error_states_actual_cap():
    with pytest.raises(ValueError, match="31"):
        admit_length(32, 32)
    with pytest.raises(ValueError, match="95"):
        admit_length(200, 96)


def test_admit_length_boundary_is_admitted():
    assert admit_length(31, 32) == 31        # == max_len - 1: accepted
    assert admit_length(95, 96) == 95
    assert admit_length(5, 32) == 16


def test_admit_buckets_cover_every_prompt_length():
    for max_len in (32, 64, 96, 256):
        buckets = set(admit_buckets(max_len))
        for plen in range(1, max_len):
            assert admit_length(plen, max_len) in buckets, (plen, max_len)


def test_prefill_chunk_shapes_closed_under_prefix_offsets():
    """Aligned chunking from ANY block-boundary start must only produce
    chunk lengths in the precomputed (warmable) set."""
    max_len, bs, chunk = 96, 16, 32
    shapes = set(prefill_chunk_shapes(max_len, bs, chunk))
    for plen in admit_buckets(max_len):
        for start in range(0, plen, bs):
            off = start
            while off < plen:
                C = min(chunk - off % chunk, plen - off)
                assert C in shapes, (plen, start, off, C)
                off += C


# ---------------------------------------------------------------------------
# paged kernel vs oracle (fast lane, interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,Dh,bs,mb", [
    (3, 4, 2, 32, 16, 6),
    (2, 4, 1, 64, 16, 4),        # MQA
    (1, 8, 4, 32, 32, 3),        # bigger blocks
])
def test_paged_kernel_matches_ref(B, H, K, Dh, bs, mb):
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.kernels.paged_attention.ref import paged_decode_attention_ref

    rng = np.random.default_rng(0)
    nb = B * mb + 2
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, K, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, K, Dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(1, nb, size=(B, mb)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, mb * bs + 1, size=(B,)), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# attention-level: paged decode bitwise == dense (fast lane)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-360m", "minicpm3-4b"])
def test_attention_decode_paged_bitwise_equals_dense(arch):
    """Scatter a dense cache's rows into a permuted block pool: the paged
    decode (write + gather + attend) must reproduce the dense ring decode
    bit for bit — same shapes, same masks, same reduction order."""
    from repro.models import attention as attn

    cfg = get_smoke_config(arch)
    p = attn.init_attention(jax.random.key(1), cfg)
    B, T, bs = 3, 32, 16
    mb = T // bs
    key = jax.random.key(3)
    dense = {k: (jax.random.normal(jax.random.fold_in(key, i), v.shape,
                                   jnp.float32) * 0.1).astype(v.dtype)
             for i, (k, v) in enumerate(
                 attn.init_kv_cache(cfg, B, T).items())}
    nb = B * mb + 1
    perm = np.random.default_rng(0).permutation(np.arange(1, nb))
    bt = jnp.asarray(perm.reshape(B, mb), jnp.int32)
    to_paged = {"k": "kp", "v": "vp", "ckv": "ckvp", "krope": "kropep"}
    paged = {}
    for dk, dv in dense.items():
        pool = jnp.zeros((nb, bs) + dv.shape[2:], dv.dtype)
        rows = dv.reshape((B * mb, bs) + dv.shape[2:])
        paged[to_paged[dk]] = pool.at[bt.reshape(-1)].set(rows)
    x = jax.random.normal(jax.random.key(2), (B, 1, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.asarray([2, 17, 30], jnp.int32)
    out_d, _ = attn.attention_decode(x, p, cfg, dense, pos)
    out_p, _ = attn.attention_decode(x, p, cfg, paged, pos, block_tables=bt)
    np.testing.assert_array_equal(np.asarray(out_d, np.float32),
                                  np.asarray(out_p, np.float32))


# ---------------------------------------------------------------------------
# engine: paged bitwise == dense across every (decoder) arch family
# ---------------------------------------------------------------------------

def _decoder_archs():
    out = []
    for a in list_archs():
        cfg = get_smoke_config(a)
        if cfg.is_encdec:
            continue                     # paged is a decoder-LM path
        marks = [] if a == "smollm-360m" else [pytest.mark.slow]
        out.append(pytest.param(a, marks=marks))
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", _decoder_archs())
def test_engine_paged_tokens_bitwise_equal_dense(arch):
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    reqs = [(7, 6), (20, 4), (4, 8)]

    def run(kv):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, kv=kv)
        for i, (pl, mn) in enumerate(reqs):
            eng.submit(_req(i, pl, mn, cfg.vocab_size))
        stats = eng.run()
        assert stats["completed"] == len(reqs)
        return eng

    engd = run("dense")
    engp = run("paged")
    for i in range(len(reqs)):
        assert engd.done[i].tokens == engp.done[i].tokens, (arch, i)
    cfg = engp.cfg
    if cfg.is_attention_free or (cfg.sliding_window is not None
                                 and cfg.mla is None):
        # nothing to page (pure SSM state / pure rolling rings): the
        # engine must fall back to the dense layout, not run a phantom
        # block pool
        assert engp.kv == "dense" and engp.allocator is None, arch
        return
    assert engp.kv == "paged"
    # eviction returned every request-owned block; only prefix-cache
    # published blocks may remain, and releasing them drains the pool
    if engp.prefix is not None:
        engp.prefix.clear()
    assert engp.allocator.allocated_blocks == 0, arch


# ---------------------------------------------------------------------------
# prefix reuse: copy-free, copy-on-write safe
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefix_shared_blocks_are_copy_on_write_safe():
    """Two identical prompts: the second maps the first's full blocks
    copy-free (refcount 2).  While the second request decodes, the shared
    blocks' pool content must stay bit-identical — nothing ever writes at
    or below the shared frontier — and both token streams must match."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=1, max_len=96, kv="paged")
    prompt = np.arange(2, 2 + 40).astype(np.int32)     # bucket 64
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    eng.run()
    assert eng.prefix is not None and len(eng.prefix) > 0
    hits_before = eng.prefix.hits

    eng.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=5))
    # step once: admission maps shared blocks; snapshot their content
    eng.step()
    assert eng.prefix.hits > hits_before
    shared = [b for b in eng._slot_blocks[0]
              if eng.allocator.refcount(b) > 1]
    assert shared, "second request shares no blocks"

    def pool_bytes():
        out = []
        for leaf in eng.state["cache"]:
            for k, v in leaf.items():
                if k in ("kp", "vp", "ckvp", "kropep"):
                    out.append(np.asarray(v[:, np.asarray(shared)],
                                          np.float32))
        return out

    before = pool_bytes()
    eng.run()
    after = pool_bytes()
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    assert eng.done[0].tokens == eng.done[1].tokens
    # refcounts fell back to cache-only after eviction
    for b in shared:
        assert eng.allocator.refcount(b) == 1


@pytest.mark.slow
def test_pool_pressure_defers_admission_but_completes():
    """A pool too small for all requests at once must defer admissions
    (blocked_admissions > 0), never deadlock or drop requests."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    # room for ~1.5 worst-case requests at a time (each needs 4 blocks)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv="paged",
                      num_blocks=7, prefix_sharing=False)
    for i in range(4):
        eng.submit(_req(i, 12, 40, cfg.vocab_size))
    stats = eng.run()
    assert stats["completed"] == 4
    assert stats["blocked_admissions"] > 0
    assert eng.allocator.allocated_blocks == 0


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chunked_prefill_isolates_running_slot():
    """A multi-chunk admission must leave the other slot's token stream
    identical to a solo run, and decode must advance between chunks (the
    <=1-chunk interleave rule, not a stop-the-world prefill)."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)

    solo = ServeEngine(cfg, params, slots=2, max_len=96, kv="paged")
    solo.submit(_req(0, 7, 24, cfg.vocab_size))
    solo.run()

    eng = ServeEngine(cfg, params, slots=2, max_len=96, kv="paged",
                      prefill="chunked", prefill_chunk=16)
    eng.submit(_req(0, 7, 24, cfg.vocab_size))
    for _ in range(3):
        eng.step()
    steps_before = eng.steps
    chunks_before = eng.prefill_chunks
    eng.submit(_req(1, 60, 4, cfg.vocab_size))     # bucket 64 -> 4 chunks
    eng.step()                          # admission starts the chunk job
    while eng._jobs:
        eng.step()
    # every chunk tick also ran a decode step for the busy slot
    assert eng.steps - steps_before >= 4
    assert eng.prefill_chunks - chunks_before == 4
    eng.run()
    assert eng.done[0].tokens == solo.done[0].tokens
    assert eng.done[1].tokens                       # intruder completed


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mixtral-8x7b", "jamba-v0.1-52b",
                                  "minicpm3-4b"])
def test_chunked_prefill_completes_on_swa_ssm_mla(arch):
    """Chunked admission must work for rolling-window (SWA), SSM-state and
    MLA-latent layers too — their chunk paths write per-row state, not
    paged blocks.  Crucially, a request admitted WHILE another slot
    decodes must produce the same tokens as the same request admitted into
    an idle engine: the batched decode step must not advance a
    mid-admission row's SSM/ring state between chunks (`_guard_rows`)."""
    cfg = get_smoke_config(arch)
    params = _params(cfg)

    solo = ServeEngine(cfg, params, slots=2, max_len=64, kv="paged",
                       prefill="chunked", prefill_chunk=16)
    solo.submit(_req(1, 30, 3, cfg.vocab_size))    # multi-chunk, idle engine
    solo.run()

    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv="paged",
                      prefill="chunked", prefill_chunk=16)
    for i, (pl, mn) in enumerate([(20, 12), (30, 3), (7, 4)]):
        eng.submit(_req(i, pl, mn, cfg.vocab_size))
    stats = eng.run()
    assert stats["completed"] == 3
    assert stats["prefill_chunks"] >= 3
    for i, (pl, mn) in enumerate([(20, 12), (30, 3), (7, 4)]):
        assert len(eng.done[i].tokens) == mn + 1
    # request 1 was admitted chunk-by-chunk while slot 0 decoded; its
    # stream must match the idle-engine run bit for bit
    assert eng.done[1].tokens == solo.done[1].tokens


@pytest.mark.slow
def test_boundary_prompt_max_len_minus_one_serves():
    """A prompt of exactly max_len - 1 tokens is admitted and generates
    its prefill token plus one decode token before max_len eviction."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    for kv in ("dense", "paged"):
        eng = ServeEngine(cfg, params, slots=1, max_len=32, kv=kv)
        eng.submit(_req(0, 31, 50, cfg.vocab_size))
        stats = eng.run()
        assert stats["completed"] == 1, kv
        assert len(eng.done[0].tokens) == 2, (kv, eng.done[0].tokens)


# ---------------------------------------------------------------------------
# stats / telemetry surface
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_stats_report_cache_pressure():
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, kv="paged")
    for i in range(3):
        eng.submit(_req(i, 10, 6, cfg.vocab_size))
    eng.step()
    eng.step()
    # kv_pressure is an INSTANTANEOUS sample: with work in flight it shows
    # the current live/allocated ratio (and falls back to 0 once drained)
    press = eng.kv_pressure()
    assert press["kv"] == "paged"
    assert 0.0 < press["kv_memory_utilization"] <= 1.0
    assert press["kv_live_tokens"] > 0
    stats = eng.run()
    assert 0.0 < stats["kv_memory_utilization"] <= 1.0
    assert stats["kv_capacity_tokens"] == eng.allocator.capacity_tokens
    assert stats["kv_peak_live_tokens"] > 0
    assert "prefix_hit_rate" in stats and "itl_p99_s" in stats
    assert eng.kv_pressure()["kv_live_tokens"] == 0    # drained
