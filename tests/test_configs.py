"""Config system: registry, derived structure, analytic param counts."""

import pytest

from repro.configs.base import (SHAPES, applicable_shapes, get_config,
                                get_smoke_config, list_archs)
from repro.models.transformer import group_period, layer_slots


def test_shapes_table():
    assert SHAPES["train_4k"].tokens == 4_096 * 256
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].mode == "decode"


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()


# Published sizes (total params).  Loose bands: our analytic count vs the
# models' advertised scale.
_EXPECTED_B = {
    "jamba-v0.1-52b": (49, 55),
    "gemma-2b": (2.0, 3.0),
    "starcoder2-3b": (2.6, 3.6),
    "smollm-360m": (0.30, 0.42),
    "minicpm3-4b": (3.4, 4.6),
    "llava-next-mistral-7b": (6.6, 7.9),
    "mixtral-8x7b": (44, 49),
    "mamba2-370m": (0.30, 0.45),
}


@pytest.mark.parametrize("arch,band", sorted(_EXPECTED_B.items()))
def test_param_count_matches_published(arch, band):
    n = get_config(arch).param_count() / 1e9
    assert band[0] <= n <= band[1], (arch, n)


def test_mixtral_active_params():
    cfg = get_config("mixtral-8x7b")
    active = cfg.active_param_count() / 1e9
    assert 11 <= active <= 15, active          # ~12.9B advertised


def test_jamba_interleave():
    cfg = get_config("jamba-v0.1-52b")
    assert group_period(cfg) == 8
    slots = layer_slots(cfg)
    assert [s["mixer"] for s in slots].count("attn") == 1    # 1:7 attn:mamba
    assert slots[7]["mixer"] == "attn"
    # MoE every 2nd layer
    assert [s["ffn"] for s in slots] == ["dense", "moe"] * 4
    assert cfg.attn_layer_indices() == (7, 15, 23, 31)


def test_mamba2_attention_free():
    cfg = get_config("mamba2-370m")
    assert cfg.is_attention_free
    assert cfg.attn_layer_indices() == ()
    assert all(s["mixer"] == "ssm" for s in layer_slots(cfg))
    assert all(s["ffn"] == "none" for s in layer_slots(cfg))


def test_long_context_applicability():
    # SSM / hybrid / SWA run long_500k; pure full-attention archs skip it.
    runs = {a for a in list_archs()
            if "long_500k" in applicable_shapes(get_config(a))}
    assert runs == {"jamba-v0.1-52b", "mamba2-370m", "mixtral-8x7b"}


def test_whisper_encdec():
    cfg = get_config("whisper-small")
    assert cfg.is_encdec and cfg.encoder_layers == 12
    assert cfg.frontend_tokens == 1500


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_config_same_family(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert smoke.family == full.family
    assert (smoke.moe is None) == (full.moe is None)
    assert (smoke.ssm is None) == (full.ssm is None)
    assert (smoke.mla is None) == (full.mla is None)
    assert smoke.is_encdec == full.is_encdec
