"""Tensor-parallel (SPMD) serving.

Host-side fast tests: mesh-shape parsing, the serve image's mesh-aware
registry key (per-(image, mesh) single-flight), and the capacity
accounting rule that a mesh-bound server is ONE unit of slot capacity.

The device-level battery — bitwise sharded-vs-single-device token parity
for GQA (Pallas paged attention under shard_map) and MLA, the
one-transfer-per-step invariant, per-device KV pool bytes, and COW/
refcount balance on sharded pools — needs more than one device, so it
runs in a subprocess with ``--xla_force_host_platform_device_count=2``
(XLA flags must be set before jax imports; same pattern as
test_dryrun.py).
"""

import dataclasses
import json
import pathlib
import subprocess
import sys
import threading

import pytest

from repro.core.autoscaler import AutoscalePolicy, FleetAutoscaler
from repro.core.images import Executable, ExecutableRegistry, PayloadImage
from repro.runtime.mesh import parse_mesh_shape, serve_mesh

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# --mesh AxB parsing
# ---------------------------------------------------------------------------

def test_parse_mesh_shape():
    assert parse_mesh_shape("1x2") == (1, 2)
    assert parse_mesh_shape("2x4") == (2, 4)
    assert parse_mesh_shape("4") == (1, 4)      # bare device count
    for bad in ("2x3x4", "ax2", "0x2", ""):
        with pytest.raises(ValueError):
            parse_mesh_shape(bad)


# ---------------------------------------------------------------------------
# serve image key + registry: compiles are per (image, mesh)
# ---------------------------------------------------------------------------

def _img(**kw):
    return PayloadImage(arch="smollm-360m", shape="smoke", mode="serve",
                        smoke=True, **kw)


def test_payload_image_key_includes_mesh_shape():
    assert _img().key() != _img(mesh_shape=(1, 2)).key()
    assert _img(mesh_shape=(1, 2)).key() != _img(mesh_shape=(2, 1)).key()


def test_registry_key_distinguishes_mesh():
    img = _img()
    k_none = ExecutableRegistry._key(img, None)
    k_mesh = ExecutableRegistry._key(img, serve_mesh((1, 1)))
    assert k_none != k_mesh


def test_registry_prefetch_single_flight_per_image_mesh(monkeypatch):
    """Two prefetches of the same (image, mesh) join one worker; a
    different mesh for the same image is a different compile."""
    reg = ExecutableRegistry()
    gate = threading.Event()
    keys = []

    def fake_pull(image, mesh=None):
        keys.append(ExecutableRegistry._key(image, mesh))
        gate.wait(10)
        return Executable(image=image, fn=None, make_inputs=None,
                          compile_seconds=0.0)

    monkeypatch.setattr(reg, "pull", fake_pull)
    img = _img()
    mesh = serve_mesh((1, 1))
    e1 = reg.prefetch(img, mesh)
    e2 = reg.prefetch(img, mesh)        # joins the in-flight prefetch
    e3 = reg.prefetch(img, None)        # distinct key -> its own worker
    assert e1 is e2
    gate.set()
    assert e1.wait(10) and e3.wait(10)
    assert reg.stats["prefetches"] == 2
    assert len(set(keys)) == 2


# ---------------------------------------------------------------------------
# capacity accounting: a mesh-bound server is ONE capacity unit
# ---------------------------------------------------------------------------

class _StubFleet:
    def __init__(self, n: int = 0):
        self.n = n
        self.draining_n = 0

    def size(self):
        return self.n

    def draining(self):
        return self.draining_n

    def scale_up(self, n):
        self.n += n
        return [object()] * n

    def scale_down(self, n):
        self.n -= n
        return []


def test_autoscaler_mesh_server_is_one_capacity_unit():
    """demand 8 against 2-slot sharded servers needs 4 servers — the 4
    devices backing each server must never multiply into capacity."""
    sig = {"demand": 8, "pool_slots_per_server": 2.0,
           "pool_mesh_devices": 4}
    fleet = _StubFleet(0)
    sc = FleetAutoscaler(fleet, None,
                         policy=AutoscalePolicy(slots_per_pilot=1),
                         signals_fn=lambda: dict(sig),
                         clock=lambda: 1000.0)
    sc.tick()
    assert fleet.size() == 4, fleet.size()


def test_pool_pressure_reports_per_server_slots_and_mesh():
    from repro.serving.dispatch import FleetDispatcher
    pool = FleetDispatcher(name="tp-test")
    for sid, slots in (("s1", 2), ("s2", 4)):
        pool.announce(sid)
        pool.report_telemetry(sid, {"slots": slots, "mesh_devices": 2,
                                    "kv_memory_utilization": 0.1})
    pp = pool.pool_pressure()
    assert pp["slots_per_server"] == pytest.approx(3.0)
    assert pp["mesh_devices"] == 2


# ---------------------------------------------------------------------------
# device battery (2 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_BATTERY = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
import dataclasses, json, sys
import jax
import jax.numpy as jnp
import repro.configs.base as b
from repro.launch.serve import make_trace
from repro.models.api import build_model, init_decode_state
from repro.runtime.mesh import MODEL_AXIS, serve_mesh
from repro.runtime.sharding import serve_param_shardings, serve_state_shardings
from repro.serving.engine import ServeEngine

assert jax.device_count() == 2
mesh = serve_mesh((1, 2))
out = {}

def run(cfg, mesh, **kw):
    import numpy as np
    from repro.serving.engine import Request
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, mesh=mesh, **kw)
    trace = make_trace(cfg.vocab_size, 6, max_len=64, seed=0, dup_rate=0.3)
    eng.run_trace(trace)
    toks = {r.rid: list(r.tokens) for r in eng.done.values()}
    # churn with a long shared prompt (several FULL blocks): admissions
    # map prefix blocks copy-free with refcount bumps, evictions return
    # them — COW/refcount balance on the SHARDED pools is the invariant
    base = (np.arange(40) % (cfg.vocab_size - 2) + 2).astype(np.int32)
    for i in range(6):
        eng.submit(Request(rid=1000 + i, prompt=base.copy(),
                           max_new_tokens=4))
    eng.run()
    toks.update({r.rid: list(r.tokens) for r in eng.done.values()})
    return eng, toks

for name, arch, flags, kw in [
        ("gqa", "starcoder2-3b", {"attn_impl": "pallas"}, {}),
        ("gqa_spec", "starcoder2-3b", {"attn_impl": "pallas"},
         {"spec": "draft", "spec_k": 3}),
        ("mla", "minicpm3-4b", {}, {})]:
    cfg = b.get_smoke_config(arch)
    if flags:
        cfg = dataclasses.replace(cfg, **flags)
    e1, t1 = run(cfg, None, **kw)
    e2, t2 = run(cfg, mesh, **kw)
    kvb = e2.kv_pool_bytes()
    out[name] = {
        "parity": t1 == t2,
        "one_transfer": e2.d2h_transfers == e2.steps,
        "kv_ratio": kvb["kv_pool_bytes_per_device"] / kvb["kv_pool_bytes"],
        "block_leaks": e2.block_leaks(),
        "prefix_hits": e2.prefix_hit_tokens,
    }

# partition rules: pools on the head/latent dim, tables replicated,
# row-parallel params (wo/down) replicated, column-parallel sharded
cfg = b.get_smoke_config("starcoder2-3b")
state = init_decode_state(cfg, 2, 64, kv="paged", num_blocks=9,
                          block_size=8)
sh = serve_state_shardings(state, mesh)
specs = {}
def walk(path, node):
    if isinstance(node, dict):
        for k, v in node.items():
            walk(path + (k,), v)
    elif isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            walk(path + (str(i),), v)
    else:
        specs["/".join(path)] = tuple(node.spec)
walk((), sh)
kp = [v for k, v in specs.items() if k.endswith("kp")]
bt = [v for k, v in specs.items() if k.endswith("block_tables")]
out["state_rules"] = {
    "kp_head_sharded": all(MODEL_AXIS in s and s[-2] == MODEL_AXIS
                           for s in kp) and bool(kp),
    "tables_replicated": all(all(a is None for a in s) for s in bt),
}
params = build_model(cfg).init(jax.random.key(0))
psh = serve_param_shardings(params, mesh)
pspecs = {}
def pwalk(path, node):
    if isinstance(node, dict):
        for k, v in node.items():
            pwalk(path + (k,), v)
    elif isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            pwalk(path + (str(i),), v)
    else:
        pspecs["/".join(path)] = tuple(node.spec)
pwalk((), psh)
wo = [v for k, v in pspecs.items() if k.endswith("wo")]
wq = [v for k, v in pspecs.items() if k.endswith("wq")]
down = [v for k, v in pspecs.items() if k.endswith("down")]
out["param_rules"] = {
    "wo_replicated": all(all(a is None for a in s) for s in wo),
    "down_replicated": all(all(a is None for a in s) for s in down),
    "wq_head_sharded": any(MODEL_AXIS in s for s in wq),
}

# kernel-level shard_map vs single-device bitwise parity
from repro.kernels.paged_attention.ops import (
    paged_decode_attention, paged_decode_attention_tp)
key = jax.random.key(7)
B, nb, bs, K, G, Dh = 2, 9, 8, 2, 2, 16
ks = jax.random.split(key, 4)
q = jax.random.normal(ks[0], (B, K * G, Dh), jnp.bfloat16)
kp = jax.random.normal(ks[1], (nb, bs, K, Dh), jnp.bfloat16)
vp = jax.random.normal(ks[2], (nb, bs, K, Dh), jnp.bfloat16)
tables = jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
cache_len = jnp.array([13, 27], jnp.int32)
ref = paged_decode_attention(q, kp, vp, tables, cache_len)
tp = paged_decode_attention_tp(q, kp, vp, tables, cache_len, mesh)
out["kernel_bitwise"] = bool(
    jnp.all(ref.astype(jnp.float32) == tp.astype(jnp.float32)))

json.dump(out, sys.stdout)
"""


@pytest.mark.slow
def test_tp_serving_battery(tmp_path):
    script = tmp_path / "battery.py"
    script.write_text(_BATTERY)
    r = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        timeout=1800,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=str(REPO))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout)
    for name in ("gqa", "gqa_spec", "mla"):
        rec = out[name]
        assert rec["parity"], (name, rec)             # bitwise tokens
        assert rec["one_transfer"], (name, rec)       # d2h == steps
        assert rec["kv_ratio"] <= 0.6, (name, rec)    # sharded pools
        assert rec["block_leaks"] == 0, (name, rec)   # COW/refcounts
        assert rec["prefix_hits"] > 0, (name, rec)    # churn exercised COW
    assert out["state_rules"] == {"kp_head_sharded": True,
                                  "tables_replicated": True}
    assert out["param_rules"] == {"wo_replicated": True,
                                  "down_replicated": True,
                                  "wq_head_sharded": True}
    assert out["kernel_bitwise"]
