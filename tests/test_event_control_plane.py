"""The event-driven control plane, as tests.

Pilot state-machine transition table, `match_wait` wake-on-submit, the
deadline-heap lease reaper re-queuing under concurrent pilots, drain-event
`run_until_drained`, the label/predicate matchmaking index, the shared
timer wheel, monitor EWMA eviction, and serve-engine admission.  All
assertions are event-driven — threads rendezvous on events/conditions, no
`time.sleep` in any assertion path.
"""

import threading
import time

import pytest

from repro.core.cluster import ClusterSim, Fleet
from repro.core.images import PayloadImage
from repro.core.monitor import Monitor, MonitorLimits
from repro.core.pilot import (InvalidTransition, Pilot, PilotConfig,
                              TERMINAL_STATES, TRANSITIONS)
from repro.core.proctable import PAYLOAD_UID, ProcessTable
from repro.core.taskrepo import TaskRepo, TaskResult
from repro.core.timerwheel import TimerWheel
from repro.serving.engine import admit_length

NOOP = PayloadImage(arch="placeholder", shape="none", mode="noop")


# ---------------------------------------------------------------------------
# pilot state machine
# ---------------------------------------------------------------------------

def test_transition_table_shape():
    # every state named in a transition is itself declared
    for src, dsts in TRANSITIONS.items():
        for d in dsts:
            assert d in TRANSITIONS, f"{src} -> {d} names unknown state"
    # terminal states have no exits and include the three documented ones
    assert TERMINAL_STATES == {"terminated", "drained", "failed"}
    # the happy path is expressible
    path = ["created", "starting", "idle", "bound", "running", "collecting",
            "idle", "terminated"]
    for a, b in zip(path, path[1:]):
        assert b in TRANSITIONS[a], f"happy path broken at {a} -> {b}"


def test_invalid_transition_rejected():
    repo = TaskRepo()
    sim = ClusterSim(repo=repo)
    (s,) = sim.provision(1)
    p = Pilot(s, repo, sim.registry)
    assert p.state == "created"
    with pytest.raises(InvalidTransition):
        p._transition("running")          # created -> running is not legal
    p._transition("starting")
    with pytest.raises(InvalidTransition):
        p._transition("collecting")


def test_pilot_state_log_follows_table():
    """A real pilot run only ever takes documented transitions."""
    sim = ClusterSim()
    sim.repo.submit(NOOP, n_steps=1)
    (s,) = sim.provision(1)
    p = sim.spawn_pilot(s, PilotConfig(max_payloads=2, idle_grace=0.2))
    assert sim.run_until_drained(timeout=60.0)
    p.join(30.0)
    assert p.state == "terminated"
    for a, b in zip(p.state_log, p.state_log[1:]):
        assert b in TRANSITIONS[a], f"undocumented transition {a} -> {b}"
    assert p.state_log[:5] == ["created", "starting", "idle", "bound",
                               "running"]


# ---------------------------------------------------------------------------
# match_wait: wake on submit, no polling
# ---------------------------------------------------------------------------

def test_match_wait_wakes_on_submit():
    repo = TaskRepo()
    got = []
    t = threading.Thread(target=lambda: got.append(
        repo.match_wait({"pilot_id": "w", "labels": {}}, timeout=30.0)))
    t0 = time.monotonic()
    t.start()
    tid = repo.submit(NOOP)
    t.join(10.0)
    elapsed = time.monotonic() - t0
    assert got and got[0] is not None and got[0].task_id == tid
    # woken by the submit notification, not the 30 s timeout
    assert elapsed < 5.0


def test_match_wait_timeout_returns_none():
    repo = TaskRepo()
    assert repo.match_wait({"pilot_id": "w", "labels": {}},
                           timeout=0.05) is None


def test_match_wait_cancel_via_kick():
    repo = TaskRepo()
    stop = threading.Event()
    got = []
    t = threading.Thread(target=lambda: got.append(
        repo.match_wait({"pilot_id": "w", "labels": {}}, timeout=30.0,
                        cancel=stop.is_set)))
    t0 = time.monotonic()
    t.start()
    stop.set()
    repo.kick()
    t.join(10.0)
    assert got == [None]
    assert time.monotonic() - t0 < 5.0


# ---------------------------------------------------------------------------
# deadline-heap lease reaper
# ---------------------------------------------------------------------------

def test_lease_expiry_requeues_to_concurrent_pilot():
    """Pilot 1 leases and dies silently; pilot 2 is parked in match_wait and
    is handed the re-queued task by the repo's own reap timer — nothing in
    the test (or the repo) polls."""
    repo = TaskRepo(lease_ttl=0.15)
    tid = repo.submit(NOOP)
    first = repo.match({"pilot_id": "p1", "labels": {}})
    assert first.task_id == tid and repo.stats()["leased"] == 1
    second = repo.match_wait({"pilot_id": "p2", "labels": {}}, timeout=10.0)
    assert second is not None and second.task_id == tid
    assert second.attempts == 2
    assert repo.stats()["leased"] == 1


def test_lease_renew_defers_reaper():
    repo = TaskRepo(lease_ttl=0.2)
    tid = repo.submit(NOOP)
    repo.match({"pilot_id": "p1", "labels": {}})
    deadline = time.monotonic() + 1.0
    while time.monotonic() < deadline:
        assert repo.renew(tid, "p1")      # keeps the lease alive
    assert repo.stats()["leased"] == 1    # never reaped while renewed
    # stop renewing: the reaper timer must fire and hand it to a waiter
    second = repo.match_wait({"pilot_id": "p2", "labels": {}}, timeout=10.0)
    assert second is not None and second.task_id == tid


def test_explicit_reap_still_works():
    repo = TaskRepo(lease_ttl=30.0)       # timer far in the future
    repo.submit(NOOP)
    task = repo.match({"pilot_id": "p1", "labels": {}})
    # force-expire by rewinding the lease, then reap explicitly.  The
    # rewound deadline also re-arms the wheel timer, so the wheel thread
    # may legally reap first — assert on the resulting state, not on which
    # thread won the race to the expired lease.
    with repo._lock:
        repo._leases[task.task_id].expires = time.monotonic() - 1.0
        repo._push_deadline(task.task_id, repo._leases[task.task_id].expires)
    repo.reap_leases()
    assert repo.stats() == {"queued": 1, "leased": 0, "done": 0,
                             "failed": 0, "pilots": 0}


# ---------------------------------------------------------------------------
# matchmaking index
# ---------------------------------------------------------------------------

def test_label_index_routing():
    repo = TaskRepo()
    t_eu = repo.submit(NOOP, require_labels={"zone": "eu"})
    t_us = repo.submit(NOOP, require_labels={"zone": "us"})
    t_open = repo.submit(NOOP, priority=-1)    # lower priority than both
    assert repo.match({"pilot_id": "p", "labels": {"zone": "us"}}
                      ).task_id == t_us
    assert repo.match({"pilot_id": "p", "labels": {}}).task_id == t_open
    assert repo.match({"pilot_id": "p", "labels": {"zone": "eu"}}
                      ).task_id == t_eu
    assert repo.stats()["queued"] == 0


def test_priority_order_across_buckets():
    repo = TaskRepo()
    lo = repo.submit(NOOP, priority=1)
    hi_lbl = repo.submit(NOOP, priority=5, require_labels={"a": "x"})
    hi_pred = repo.submit(NOOP, priority=9,
                          requirements=lambda ad: ad["labels"].get("a") == "x")
    ad = {"pilot_id": "p", "labels": {"a": "x"}}
    assert repo.match(ad).task_id == hi_pred
    assert repo.match(ad).task_id == hi_lbl
    assert repo.match(ad).task_id == lo


def test_predicate_rejection_keeps_fifo_order():
    """A predicate task rejected by one pilot keeps its queue position —
    re-pushing must not starve it behind newer same-priority tasks."""
    repo = TaskRepo()
    gpu_only = lambda ad: ad["labels"].get("accel") == "gpu"   # noqa: E731
    anyone = lambda ad: True                                   # noqa: E731
    t1 = repo.submit(NOOP, requirements=gpu_only)
    t2 = repo.submit(NOOP, requirements=anyone)
    t3 = repo.submit(NOOP, requirements=anyone)
    # CPU pilot: rejects t1, leases t2 (t1 is popped and re-pushed)
    assert repo.match({"pilot_id": "cpu", "labels": {}}).task_id == t2
    # GPU pilot: must get the OLDER t1, not t3
    assert repo.match({"pilot_id": "gpu",
                       "labels": {"accel": "gpu"}}).task_id == t1
    assert repo.match({"pilot_id": "cpu", "labels": {}}).task_id == t3


def test_broken_predicate_does_not_crash_matchmaking():
    repo = TaskRepo()
    repo.submit(NOOP, requirements=lambda ad: ad["no_such_key"] > 0)
    ok = repo.submit(NOOP)
    assert repo.match({"pilot_id": "p", "labels": {}}).task_id == ok
    assert repo.match({"pilot_id": "p", "labels": {}}) is None


# ---------------------------------------------------------------------------
# drain event
# ---------------------------------------------------------------------------

def test_run_until_drained_blocks_on_event():
    sim = ClusterSim()
    assert sim.run_until_drained(timeout=0.05)       # empty repo is drained
    tids = [sim.repo.submit(NOOP, n_steps=1) for _ in range(3)]
    assert not sim.repo.drain_done()
    fleet = sim.spawn_fleet(2, PilotConfig(max_payloads=4, idle_grace=0.2))
    assert sim.run_until_drained(timeout=60.0)
    fleet.join_all(30.0)
    for tid in tids:
        assert sim.repo.result(tid).exitcode == 0


def test_failed_complete_release_has_no_transient_drain():
    """Between complete(exit!=0) and release(failed=True) the repo must not
    look drained — the lease is held until the release lands."""
    repo = TaskRepo()
    repo.submit(NOOP, max_attempts=3)
    task = repo.match({"pilot_id": "p", "labels": {}})
    assert repo.complete(TaskResult(task.task_id, "p", 1, {})) is False
    assert not repo.drain_done()          # still leased
    repo.release(task, failed=True)
    assert not repo.drain_done()          # re-queued for retry
    assert repo.stats()["queued"] == 1


# ---------------------------------------------------------------------------
# fleet scaling
# ---------------------------------------------------------------------------

def test_fleet_scale_up_down():
    sim = ClusterSim()
    fleet = sim.spawn_fleet(2, PilotConfig(max_payloads=4, idle_grace=30.0))
    assert fleet.size() == 2
    fleet.scale_up(1)
    assert fleet.size() == 3
    # back-to-back single-pilot scale-downs must pick distinct victims
    victims = fleet.scale_down(1) + fleet.scale_down(1)
    assert len(victims) == 2 and victims[0] is not victims[1]
    for v in victims:
        v.join(10.0)
        assert v.state == "drained"
    assert fleet.size() == 1
    fleet.drain_all()
    fleet.join_all(10.0)
    assert fleet.size() == 0


# ---------------------------------------------------------------------------
# timer wheel
# ---------------------------------------------------------------------------

def test_timerwheel_one_shot_and_cancel():
    wheel = TimerWheel("test-wheel")
    fired = threading.Event()
    wheel.call_later(0.01, fired.set)
    assert fired.wait(5.0)
    held = wheel.call_later(0.05, lambda: pytest.fail("cancelled timer fired"))
    held.cancel()
    probe = threading.Event()
    wheel.call_later(0.1, probe.set)      # fires after the cancelled slot
    assert probe.wait(5.0)


def test_timerwheel_periodic():
    wheel = TimerWheel("test-wheel-2")
    hits = threading.Semaphore(0)
    t = wheel.call_periodic(0.01, hits.release)
    for _ in range(3):
        assert hits.acquire(timeout=5.0)
    t.cancel()


def test_timerwheel_callback_errors_are_visible_not_swallowed():
    """A raising callback must land on the wheel's error ledger (a silently
    dead lease reaper would disable lease expiry fleet-wide), a raising
    PERIODIC timer stays scheduled, and other timers keep being serviced."""
    wheel = TimerWheel("test-wheel-err")
    hits = threading.Semaphore(0)

    def boom():
        raise RuntimeError("kaboom")

    wheel.call_later(0.0, boom, name="bad-oneshot")
    bad = wheel.call_periodic(0.01, boom, name="bad-periodic")
    good = wheel.call_periodic(0.01, hits.release)
    for _ in range(3):
        assert hits.acquire(timeout=5.0)     # wheel survived the raisers
    bad.cancel()
    good.cancel()
    assert wheel.error_count >= 2            # one-shot + >=1 periodic firing
    stats = wheel.stats()
    assert stats["errors"] == wheel.error_count
    names = [n for n, _ in stats["last_errors"]]
    assert "bad-oneshot" in names and "bad-periodic" in names
    assert any("kaboom" in msg for _, msg in stats["last_errors"])


def test_scheduler_metrics_expose_timer_errors():
    wheel = TimerWheel("test-wheel-metrics")
    repo = TaskRepo(wheel=wheel)
    assert repo.scheduler_metrics()["timer_errors"] == 0
    fired = threading.Event()

    def boom():
        fired.set()
        raise RuntimeError("reaper crash")

    wheel.call_later(0.0, boom, name="crashing-reaper")
    assert fired.wait(5.0)
    deadline = time.monotonic() + 5.0
    while (repo.scheduler_metrics()["timer_errors"] == 0
           and time.monotonic() < deadline):
        time.sleep(0.005)
    assert repo.scheduler_metrics()["timer_errors"] == 1


# ---------------------------------------------------------------------------
# monitor EWMA eviction (leak fix)
# ---------------------------------------------------------------------------

def test_monitor_ewma_evicted_on_exit():
    pt = ProcessTable()
    mon = Monitor(pt, MonitorLimits(max_wall=1e9), fleet_median_fn=lambda: 0.1)
    for i in range(50):
        e = pt.register(PAYLOAD_UID, f"w{i}")
        for _ in range(3):
            pt.heartbeat(e.pid, 0.1)
        mon.scan()
        assert e.pid in mon._ewma
        pt.mark_exited(e.pid, 0)
        mon.scan()
        assert e.pid not in mon._ewma
    assert mon._ewma == {}


# ---------------------------------------------------------------------------
# proctable events
# ---------------------------------------------------------------------------

def test_proctable_fires_step_and_exit_events():
    pt = ProcessTable()
    events = []
    pt.subscribe(lambda kind, e: events.append((kind, e.pid)))
    e = pt.register(PAYLOAD_UID, "w")
    pt.heartbeat(e.pid, 0.1)
    pt.mark_exited(e.pid, 0)
    pt.mark_exited(e.pid, 0)              # second exit: no duplicate event
    assert events == [("step", e.pid), ("exit", e.pid)]
    pt.unsubscribe(pt._listeners[0] if pt._listeners else None)


# ---------------------------------------------------------------------------
# serve-engine admission (satellite: explicit rejection, no silent crop)
# ---------------------------------------------------------------------------

def test_admit_length_buckets_and_rejects():
    assert admit_length(1, 256) == 16
    assert admit_length(16, 256) == 16
    assert admit_length(17, 256) == 32
    # bucket capped below max_len so decode keeps >=1 free cache position
    assert admit_length(200, 256) == 255
    with pytest.raises(ValueError):
        admit_length(256, 256)            # no room for a generated token
    with pytest.raises(ValueError):
        admit_length(300, 256)


def test_mixed_labels_and_predicate_requirements():
    """A task carrying BOTH require_labels and a predicate must satisfy
    both — the label constraint is not dropped in the predicate bucket."""
    repo = TaskRepo()
    tid = repo.submit(NOOP, require_labels={"accel": "tpu"},
                      requirements=lambda ad: ad.get("n_devices", 0) >= 2)
    # matching predicate but wrong labels: must NOT match
    assert repo.match({"pilot_id": "p", "labels": {}, "n_devices": 4}) is None
    # right labels but failing predicate: must NOT match
    assert repo.match({"pilot_id": "p", "labels": {"accel": "tpu"},
                       "n_devices": 1}) is None
    # both satisfied
    got = repo.match({"pilot_id": "p", "labels": {"accel": "tpu"},
                      "n_devices": 2})
    assert got is not None and got.task_id == tid


def test_runtime_thread_stops_after_terminate():
    """Pilot termination must close the executor's container-runtime thread
    — elastic churn would otherwise leak one parked thread per pilot."""
    sim = ClusterSim()
    sim.repo.submit(NOOP, n_steps=1)
    (s,) = sim.provision(1)
    p = sim.spawn_pilot(s, PilotConfig(max_payloads=1, idle_grace=0.2))
    assert sim.run_until_drained(timeout=60.0)
    p.join(10.0)
    rt = p.executor._runtime
    assert rt is not None
    rt.join(5.0)
    assert not rt.is_alive()


def test_soft_crash_reaches_terminal_state():
    """A pilot whose start step raises (no devices) must land in 'failed',
    not linger in a non-terminal state that Fleet/live_pilots counts."""
    sim = ClusterSim()
    (s,) = sim.provision(1)
    s.devices = []                        # invalid slice
    p = sim.spawn_pilot(s, PilotConfig(max_payloads=1, idle_grace=0.1))
    p.join(10.0)
    assert p.state == "failed"
    assert p.state in TERMINAL_STATES
    assert sim.live_pilots() == []
    assert s.released                     # slice still handed back
