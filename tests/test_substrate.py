"""Substrate layers: checkpointing, compression, elasticity, data pipeline,
sharding rules, HLO collective parsing, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.ckpt import checkpoint as ck
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.hlo_stats import collective_stats, type_bytes
from repro.runtime import compression as comp
from repro.runtime.elastic import plan_remesh, viable_data_axis
from repro.runtime.mesh import MeshSpec


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(k=0):
    key = jax.random.key(k)
    return {"a": jax.random.normal(key, (4, 3)),
            "b": [jnp.arange(5), {"c": jnp.float32(2.5)}]}


def test_ckpt_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree()
    ck.save(d, 3, t)
    got = ck.restore(d, 3, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ck.save(d, s, _tree(s), keep=2)
    assert ck.latest_step(d) == 5
    assert sorted(ck.all_steps(d)) == [4, 5]


def test_ckpt_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ck.restore(d, 1, {"a": jnp.zeros((3, 3))})


def test_ckpt_async(tmp_path):
    d = str(tmp_path)
    acp = ck.AsyncCheckpointer(d, keep=3)
    for s in (1, 2, 3):
        acp.save(s, _tree(s))
    acp.wait()
    assert ck.latest_step(d) == 3


def test_ckpt_no_tmp_leftovers(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _tree())
    assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


# checkpoint crash-window + dtype-validation tests live in
# tests/test_durability.py (hypothesis-free, so they run even where this
# module skips)


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(4, 200))
def test_compression_error_bound(scale, n):
    g = jax.random.normal(jax.random.key(n), (n,)) * scale
    r = jnp.zeros_like(g)
    dq, res = comp._quantize_leaf(g, r)
    # quantization error per element <= scale/2 where scale = max|g|/127
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(res))) <= step * 0.5 + 1e-9
    np.testing.assert_allclose(np.asarray(dq + res), np.asarray(g), rtol=1e-5)


def test_error_feedback_accumulates():
    """A constant tiny gradient below one quantization step must still get
    through over multiple steps thanks to the residual."""
    g = jnp.full((8,), 0.001)
    big = jnp.zeros((8,)).at[0].set(1.0)       # sets the scale
    grads = g + big
    res = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        dq, res = comp._quantize_leaf(grads, res)
        total = total + dq
    # mean transmitted value over 50 steps approximates the true signal
    np.testing.assert_allclose(np.asarray(total[1:] / 50),
                               np.asarray(g[1:]), rtol=0.2)


def test_compression_payload_accounting():
    g = {"w": jnp.zeros((100, 10), jnp.float32)}
    raw, compressed = comp.payload_bytes(g)
    assert raw == 4000 and compressed == 1004


# ---------------------------------------------------------------------------
# elastic remesh planning
# ---------------------------------------------------------------------------

def test_viable_data_axis():
    assert viable_data_axis(16, 256) == 16
    assert viable_data_axis(15, 256) == 8       # largest divisor <= 15... wait
    assert viable_data_axis(12, 256) == 8
    assert viable_data_axis(1, 256) == 1


def test_plan_remesh_shrink_and_noop():
    old = MeshSpec((16, 16), ("data", "model"))
    plan = plan_remesh(old, 12, 16, 256)
    assert plan.new_mesh.shape == (8, 16)
    assert "restore-checkpoint" in plan.actions
    plan2 = plan_remesh(old, 16, 16, 256)
    assert plan2.actions == ("no-op",)


def test_plan_remesh_no_slices_raises():
    with pytest.raises(ValueError):
        plan_remesh(None, 0, 16, 256)


# the empty-fleet NoViableMeshError boundary tests live in
# tests/test_durability.py (hypothesis-free)


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_learnable():
    cfg = SyntheticConfig(vocab_size=64, seq_len=128, global_batch=4,
                          structure=0.9)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # targets are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
    # the Markov rule holds ~structure of the time
    table = d1._table
    follows = (table[b1["tokens"][:, :-1]] == b1["tokens"][:, 1:]).mean()
    assert follows > 0.8


# ---------------------------------------------------------------------------
# sharding rules (pure spec logic via a shim mesh)
# ---------------------------------------------------------------------------

class _ShimMesh:
    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape)


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from jax.tree_util import DictKey
    from repro.runtime.sharding import param_spec

    mesh = _ShimMesh((16, 16), ("data", "model"))
    path = (DictKey("layers"), DictKey("mixer"), DictKey("wq"))
    # (groups, D, H, Dh): H=32 divisible -> TP on heads; FSDP on D
    assert param_spec(path, (4, 4096, 32, 128), mesh, "train") == \
        P(None, "data", "model", None)
    # serve mode: no FSDP
    assert param_spec(path, (4, 4096, 32, 128), mesh, "serve") == \
        P(None, None, "model", None)
    # H=15 not divisible by 16 -> TP degrades away (smollm)
    assert param_spec(path, (4, 960, 15, 64), mesh, "serve") == P(None, None, None, None)
    # embed: vocab over model, D FSDP over data
    assert param_spec((DictKey("embed"),), (256000, 2048), mesh, "train") == \
        P("model", "data")
    # odd vocab (granite 49155): degrades to FSDP-only
    assert param_spec((DictKey("embed"),), (49155, 1536), mesh, "train") == \
        P(None, "data")


def test_param_spec_moe_ep_partition():
    from jax.sharding import PartitionSpec as P
    from jax.tree_util import DictKey
    from repro.runtime.sharding import param_spec

    mesh = _ShimMesh((16, 16), ("data", "model"))
    path = (DictKey("layers"), DictKey("ffn"), DictKey("up"))
    shape = (4, 16, 4096, 14336)                 # (groups, E, D, F)
    assert param_spec(path, shape, mesh, "train", moe_partition="ep") == \
        P(None, "model", "data", None)
    assert param_spec(path, shape, mesh, "train", moe_partition="tp") == \
        P(None, None, "data", "model")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ag = bf16[256,1024]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[128,128]{1,0} all-reduce(%x), to_apply=%add
  %big = f32[256,512]{1,0} fusion(%y)
  %rs = f32[16,512]{1,0} reduce-scatter(%big), dimensions={0}
  %cp = u8[64]{0} collective-permute(%z)
  ROOT %t = (bf16[256,1024]{1,0}) tuple(%ag)
}
"""


def test_type_bytes():
    assert type_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
    assert type_bytes("(f32[8], s8[4])") == 8 * 4 + 4
    assert type_bytes("f32[]") == 4


def test_collective_stats_conventions():
    st_ = collective_stats(_HLO)
    assert st_["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1, "collective-permute": 1}
    ag = 256 * 1024 * 2
    ar = 2 * 128 * 128 * 4                       # 2x multiplier
    rs = 256 * 512 * 4                           # operand bytes
    cp = 64
    assert st_["bytes"]["all-gather"] == ag
    assert st_["bytes"]["all-reduce"] == ar
    assert st_["bytes"]["reduce-scatter"] == rs
    assert st_["total_bytes"] == ag + ar + rs + cp


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_engine_completes_all():
    from repro.configs.base import get_smoke_config
    from repro.models.api import build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("smollm-360m")
    params = build_model(cfg).init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, size=5 + i),
                           max_new_tokens=4 + (i % 3)))
    stats = eng.run()
    assert stats["completed"] == 5
    assert all(len(r.tokens) == r.max_new_tokens + 1
               for r in eng.done.values())
    assert 0 < stats["slot_utilization"] <= 1.0


@pytest.mark.slow
def test_serving_greedy_is_deterministic():
    from repro.configs.base import get_smoke_config
    from repro.models.api import build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke_config("smollm-360m")
    params = build_model(cfg).init(jax.random.key(0))
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, slots=1, max_len=48)
        eng.submit(Request(rid=0, prompt=np.arange(6) % cfg.vocab_size,
                           max_new_tokens=6))
        eng.run()
        outs.append(tuple(eng.done[0].tokens))
    assert outs[0] == outs[1]
