"""The paper's functional claims, as tests.

§3.3 unprivileged late binding (pod-scoped capability, image patch, warm
rebinding), §3.4 monitoring via the shared process table + uid model,
§3.5 env setup + exit-code relay, §3.6 cleanup by restart, plus the dHTC
fault-tolerance substrate: leases, re-queue on node failure,
first-completion-wins, straggler kill, checkpoint resume.
"""

import time

import pytest

from repro.core.arena import SharedArena
from repro.core.cluster import ClusterSim
from repro.core.images import ExecutableRegistry, PLACEHOLDER, PayloadImage
from repro.core.latebind import PayloadExecutor, PermissionError_, PodPatchCapability
from repro.core.monitor import Monitor, MonitorLimits
from repro.core.pilot import Pilot, PilotConfig
from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable
from repro.core.taskrepo import PayloadTask, TaskRepo, TaskResult

SMOKE_TRAIN = PayloadImage("smollm-360m", "smoke", "train")
SMOKE_DECODE = PayloadImage("smollm-360m", "smoke", "decode")


# ---------------------------------------------------------------------------
# §3.3 late binding
# ---------------------------------------------------------------------------

def _executor(tmp_path):
    arena = SharedArena(str(tmp_path / "arena"))
    pt = ProcessTable()
    reg = ExecutableRegistry()
    ex = PayloadExecutor("pod-A", arena, pt, reg)
    return ex, arena, pt, reg


def test_placeholder_installed_at_creation(tmp_path):
    ex, *_ = _executor(tmp_path)
    assert ex.image == PLACEHOLDER
    assert ex.state == "unbound"


def test_pod_patch_capability_is_pod_scoped(tmp_path):
    """The §3.3 authorization: 'pod patch' only inside its own pod."""
    ex, *_ = _executor(tmp_path)
    with pytest.raises(PermissionError_):
        ex.patch_image(PodPatchCapability(pod_id="pod-B"), SMOKE_TRAIN)
    exe = ex.patch_image(PodPatchCapability(pod_id="pod-A"), SMOKE_TRAIN)
    assert ex.state == "bound" and exe.image == SMOKE_TRAIN


def test_wait_for_spec_timeout_is_exit_124(tmp_path):
    """Payload container started but no startup spec ever appears."""
    ex, arena, _, _ = _executor(tmp_path)
    ex.patch_image(PodPatchCapability("pod-A"), SMOKE_DECODE)
    ex.start(spec_timeout=0.2)
    ex.join(timeout=10.0)
    assert arena.read_exit()["exitcode"] == 124


def test_warm_rebind_skips_compilation(tmp_path):
    """The measurable late-binding win: second bind of the same image is a
    cache hit (image already 'pulled' on the node)."""
    ex, _, _, reg = _executor(tmp_path)
    cap = PodPatchCapability("pod-A")
    e1 = ex.patch_image(cap, SMOKE_DECODE)
    e2 = ex.patch_image(cap, SMOKE_DECODE)
    assert not e1.cached and e2.cached
    assert reg.stats["hits"] == 1
    # single-flight: concurrent pulls compile once
    import threading
    reg2 = ExecutableRegistry()
    outs = []
    ts = [threading.Thread(target=lambda: outs.append(
        reg2.pull(SMOKE_DECODE))) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert reg2.stats["misses"] == 1 and len(outs) == 4


def test_restart_invalidates_waiting_container(tmp_path):
    """reset() while the old container waits for a spec: the old generation
    must not execute a spec published after the restart."""
    ex, arena, pt, _ = _executor(tmp_path)
    cap = PodPatchCapability("pod-A")
    ex.patch_image(cap, SMOKE_DECODE)
    ex.start(spec_timeout=5.0)
    ex.reset()
    assert ex.state == "bound"
    ex.start(spec_timeout=5.0)
    arena.publish_startup_spec({"n_steps": 1})
    ex.join(timeout=30.0)
    assert arena.read_exit()["exitcode"] == 0


# ---------------------------------------------------------------------------
# §3.4 process table + uid model
# ---------------------------------------------------------------------------

def test_uid_visibility_and_signal_rules():
    pt = ProcessTable()
    pe = pt.register(PILOT_UID, "pilot")
    we = pt.register(PAYLOAD_UID, "payload")
    # pilot sees all; payload sees only its own uid
    assert {e.pid for e in pt.entries()} == {pe.pid, we.pid}
    assert {e.pid for e in pt.entries(viewer_uid=PAYLOAD_UID)} == {we.pid}
    # payload cannot signal the pilot (EPERM), pilot can signal payload
    assert not pt.kill(pe.pid, signaller_uid=PAYLOAD_UID)
    assert pt.kill(we.pid, signaller_uid=PILOT_UID)
    assert we.stop.is_set()


def test_monitor_wall_limit_kills():
    pt = ProcessTable()
    e = pt.register(PAYLOAD_UID, "payload")
    mon = Monitor(pt, MonitorLimits(max_wall=0.5))
    acts = mon.scan(now=e.started + 1.0)
    assert [a.kind for a in acts] == ["kill-wall"]
    assert e.stop.is_set()


def test_monitor_straggler_detection():
    pt = ProcessTable()
    e = pt.register(PAYLOAD_UID, "payload")
    for _ in range(5):
        pt.heartbeat(e.pid, 1.0)                 # 1 s/step
    mon = Monitor(pt, MonitorLimits(max_wall=1e9, straggler_factor=3.0),
                  fleet_median_fn=lambda: 0.1)   # fleet does 100 ms/step
    acts = mon.scan()
    assert [a.kind for a in acts] == ["kill-straggler"]


def test_monitor_healthy_payload_untouched():
    pt = ProcessTable()
    e = pt.register(PAYLOAD_UID, "payload")
    for _ in range(5):
        pt.heartbeat(e.pid, 0.1)
    mon = Monitor(pt, MonitorLimits(max_wall=1e9, straggler_factor=3.0),
                  fleet_median_fn=lambda: 0.1)
    assert mon.scan() == []
    assert not e.stop.is_set()


# ---------------------------------------------------------------------------
# §3.5 env + exit-code relay, §3.6 cleanup
# ---------------------------------------------------------------------------

def test_env_and_exit_relay_through_arena(tmp_path):
    arena = SharedArena(str(tmp_path / "a"))
    arena.write_env({"seed": 3, "pilot": "p1"})
    assert arena.read_env()["seed"] == 3
    arena.report_exit(7, {"steps": 2})
    got = arena.read_exit()
    assert got["exitcode"] == 7 and got["telemetry"]["steps"] == 2


def test_wipe_shared_preserves_private(tmp_path):
    arena = SharedArena(str(tmp_path / "a"))
    arena.stage_file("in/data.bin", b"x")
    with open(f"{arena.private}/lease.json", "w") as f:
        f.write("{}")
    arena.wipe_shared()
    assert arena.shared_files() == []
    import os
    assert os.path.exists(f"{arena.private}/lease.json")


# ---------------------------------------------------------------------------
# TaskRepo: matchmaking, leases, first-wins
# ---------------------------------------------------------------------------

def test_matchmaking_requirements_and_priority():
    repo = TaskRepo()
    t_gpu = repo.submit(SMOKE_TRAIN, priority=0,
                        requirements=lambda ad: ad["labels"].get("accel") == "tpu")
    t_any = repo.submit(SMOKE_DECODE, priority=5)
    ad = {"pilot_id": "p", "labels": {}}
    got = repo.match(ad)
    assert got.task_id == t_any                 # higher priority, matching
    assert repo.match(ad) is None               # tpu-only task doesn't match
    got2 = repo.match({"pilot_id": "p2", "labels": {"accel": "tpu"}})
    assert got2.task_id == t_gpu


def test_lease_expiry_requeues():
    repo = TaskRepo(lease_ttl=0.05)
    tid = repo.submit(SMOKE_TRAIN)
    task = repo.match({"pilot_id": "p1", "labels": {}})
    assert task.task_id == tid
    assert repo.stats()["leased"] == 1
    # the repo-owned deadline-heap timer expires the lease and hands the
    # re-queued task to a parked pilot — nobody polls or reaps by hand
    got = repo.match_wait({"pilot_id": "p2", "labels": {}}, timeout=10.0)
    assert got is not None and got.task_id == tid and got.attempts == 2
    repo.release(got)
    assert repo.stats() == {"queued": 1, "leased": 0, "done": 0,
                             "failed": 0, "pilots": 0}


def test_first_completion_wins():
    repo = TaskRepo()
    tid = repo.submit(SMOKE_TRAIN)
    repo.match({"pilot_id": "p1", "labels": {}})
    r1 = TaskResult(tid, "p1", 0, {})
    r2 = TaskResult(tid, "p2", 0, {})
    assert repo.complete(r1) is True
    assert repo.complete(r2) is False           # speculative duplicate dropped
    assert repo.result(tid).pilot_id == "p1"


def test_failed_payload_retries_then_fails():
    repo = TaskRepo()
    tid = repo.submit(SMOKE_TRAIN, max_attempts=2)
    for attempt in range(2):
        t = repo.match({"pilot_id": "p", "labels": {}})
        assert t is not None and t.attempts == attempt + 1
        repo.complete(TaskResult(tid, "p", 1, {}))
        repo.release(t, failed=True)
    assert repo.match({"pilot_id": "p", "labels": {}}) is None
    assert repo.stats()["failed"] == 1


# ---------------------------------------------------------------------------
# Integration: full pilot lifecycle
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pilot_runs_multiple_payloads_one_slice():
    """One resource claim, several different payloads — the core late-binding
    value proposition (multi-payload pilot)."""
    sim = ClusterSim()
    t1 = sim.repo.submit(PayloadImage("smollm-360m", "smoke", "train"),
                         n_steps=2)
    t2 = sim.repo.submit(PayloadImage("gemma-2b", "smoke", "decode"),
                         n_steps=2)
    (s,) = sim.provision(1)
    p = sim.spawn_pilot(s, PilotConfig(max_payloads=4, idle_grace=1.0))
    assert sim.run_until_drained(timeout=300.0)
    sim.join_all(30.0)
    assert sim.repo.result(t1).exitcode == 0
    assert sim.repo.result(t2).exitcode == 0
    assert len(p.history) == 2
    assert s.released                            # step (h): slice released


@pytest.mark.slow
def test_node_failure_requeue_and_recovery():
    """Hard pilot death mid-payload -> lease expires -> second pilot
    completes the task (at-least-once delivery)."""
    repo = TaskRepo(lease_ttl=0.5)
    sim = ClusterSim(repo=repo)
    tid = repo.submit(PayloadImage("smollm-360m", "smoke", "train"),
                      n_steps=3, max_attempts=5)
    (s1,) = sim.provision(1)
    p1 = sim.spawn_pilot(s1, PilotConfig(max_payloads=2, idle_grace=0.5))
    time.sleep(0.3)                              # let it lease the task
    sim.fail_node(s1.slice_id)
    p1.join(30.0)
    assert p1.state == "failed"
    (s2,) = sim.provision(1)
    sim.spawn_pilot(s2, PilotConfig(max_payloads=2, idle_grace=2.0))
    assert sim.run_until_drained(timeout=300.0)
    sim.join_all(30.0)
    res = repo.result(tid)
    assert res is not None and res.exitcode == 0
    assert res.pilot_id != p1.pilot_id


@pytest.mark.slow
def test_checkpoint_resume_across_pilots(tmp_path):
    """Train payload checkpoints; after a re-queue the successor resumes
    from the last step instead of starting over."""
    repo = TaskRepo(lease_ttl=60.0)
    sim = ClusterSim(repo=repo)
    ck = str(tmp_path / "ck")
    resume = {"ckpt_dir": ck, "ckpt_every": 2}
    tid = repo.submit(PayloadImage("smollm-360m", "smoke", "train"),
                      n_steps=4, resume=resume)
    (s,) = sim.provision(1)
    sim.spawn_pilot(s, PilotConfig(max_payloads=2, idle_grace=1.0))
    assert sim.run_until_drained(timeout=300.0)
    sim.join_all(30.0)
    from repro.ckpt import checkpoint as ckpt
    assert ckpt.latest_step(ck) == 4
    # resubmit the same task: it must resume from step 4 (0 new steps run)
    tid2 = repo.submit(PayloadImage("smollm-360m", "smoke", "train"),
                       n_steps=4, resume=resume)
    (s2,) = sim.provision(1)
    sim.spawn_pilot(s2, PilotConfig(max_payloads=2, idle_grace=1.0))
    assert sim.run_until_drained(timeout=300.0)
    sim.join_all(30.0)
    assert repo.result(tid2).telemetry.get("resumed_from") == 4
