"""Trip-count-aware HLO cost model: exactness on known graphs + the scan
under-counting regression it exists to fix."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import module_cost


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_exact():
    A = jnp.zeros((128, 256))
    B = jnp.zeros((256, 512))
    c = module_cost(_hlo(jnp.dot, A, B))
    exact = 2 * 128 * 256 * 512
    assert abs(c.flops - exact) / exact < 0.05
    io = (128 * 256 + 256 * 512 + 128 * 512) * 4
    assert abs(c.bytes_fused - io) / io < 0.1


def test_scan_multiplies_trip_count():
    """THE regression: XLA cost_analysis counts a scan body once."""
    W = jnp.zeros((8, 64, 64))
    x = jnp.zeros((64, 64))

    def f(x, W):
        return jax.lax.scan(lambda c, w: (jnp.dot(c, w), None), x, W)[0]

    compiled = jax.jit(f).lower(x, W).compile()
    one = 2 * 64 ** 3
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):         # some jax versions wrap in a list
        ca = ca[0]
    xla_says = ca["flops"]
    ours = module_cost(compiled.as_text()).flops
    assert xla_says < 2 * one                 # the bug we work around
    assert 7.5 * one <= ours <= 9 * one       # the correct count


def test_nested_scan():
    W = jnp.zeros((8, 64, 64))
    x = jnp.zeros((64, 64))

    def f(x, W):
        def outer(c, _):
            return jax.lax.scan(lambda y, w: (jnp.dot(y, w), None), c, W)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = module_cost(_hlo(f, x, W))
    one = 2 * 64 ** 3
    assert 22 * one <= c.flops <= 27 * one


def test_fused_bytes_exclude_elementwise_chains():
    x = jnp.zeros((256, 256))

    def f(x):
        y = jnp.dot(x, x)
        return jnp.tanh(y) * 2.0 + 1.0         # fuses into the dot's output

    c = module_cost(_hlo(f, x))
    dot_io = 3 * 256 * 256 * 4
    # fused convention: ~dot IO only; unfused counts the elementwise chain
    assert c.bytes_fused < dot_io * 1.6
    assert c.bytes > c.bytes_fused


def test_dynamic_update_slice_counts_update_not_buffer():
    cache = jnp.zeros((1024, 64))
    row = jnp.zeros((1, 64))

    def f(cache, row):
        return jax.lax.dynamic_update_slice(cache, row, (5, 0))

    c = module_cost(_hlo(f, cache, row))
    assert c.bytes_fused <= 4 * 64 * 4 * 4    # ~2x update bytes, not 256 KB
