"""Dry-run machinery: specs, constrain(), layouts, and one real
(subprocess) lower+compile against the production mesh."""

import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, get_smoke_config
from repro.launch.specs import input_specs

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (no allocation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_input_specs_are_abstract(mode):
    cfg = get_config("gemma-2b")
    specs = input_specs(cfg, SHAPES["decode_32k" if mode == "decode"
                                   else "train_4k"], mode)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_train_specs_shapes():
    cfg = get_config("mixtral-8x7b")
    state, batch = input_specs(cfg, SHAPES["train_4k"], "train")
    assert batch["tokens"].shape == (256, 4096)
    n = sum(l.size for l in jax.tree.leaves(state["params"]))
    assert abs(n - cfg.param_count()) / cfg.param_count() < 0.02


def test_decode_specs_cache_rolling_swa():
    cfg = get_config("mixtral-8x7b")             # SWA window 4096
    _, state = input_specs(cfg, SHAPES["long_500k"], "decode")
    (kv,) = [l for l in jax.tree.leaves(state["cache"])
             if l.ndim == 5][:1]
    assert kv.shape[2] == 4096                   # rolling window, not 524288


# ---------------------------------------------------------------------------
# constrain(): no-op without context; correct specs with context
# ---------------------------------------------------------------------------

def test_constrain_noop_without_context():
    from repro.runtime.sharding import constrain
    x = jnp.zeros((4, 8))
    assert constrain(x, "b.") is x


def test_constrain_applies_in_context():
    from repro.runtime.sharding import activation_sharding, constrain
    mesh = jax.make_mesh((1,), ("data",))
    with activation_sharding(mesh, "2d"):
        out = jax.jit(lambda x: constrain(x, "b."))(jnp.zeros((4, 8)))
    assert out.shape == (4, 8)


def test_constrain_conflicting_axes_skipped():
    from repro.runtime.sharding import activation_sharding, constrain
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.zeros((4, 4))
    with activation_sharding(mesh, "2d"):
        # batch and expert dims both want "data" -> constraint skipped
        out = constrain(x, "bd")
        assert out is x


# ---------------------------------------------------------------------------
# the real thing: one cheap cell lowered+compiled on the 16x16 mesh in a
# subprocess (XLA_FLAGS isolation)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=str(REPO))
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads((REPO / "results" / "dryrun" / "pod16x16" /
                      "mamba2-370m__decode_32k.json").read_text())
    assert rec["mesh"]["shape"] == [16, 16]
    t = rec["roofline"]
    assert t["compute_s"] > 0 and t["memory_s"] > 0
    assert rec["hlo_cost"]["flops"] > 0
