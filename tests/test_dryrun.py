"""Dry-run machinery: specs, constrain(), layouts, and one real
(subprocess) lower+compile against the production mesh."""

import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, get_smoke_config
from repro.launch.specs import input_specs

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (no allocation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_input_specs_are_abstract(mode):
    cfg = get_config("gemma-2b")
    specs = input_specs(cfg, SHAPES["decode_32k" if mode == "decode"
                                   else "train_4k"], mode)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_train_specs_shapes():
    cfg = get_config("mixtral-8x7b")
    state, batch = input_specs(cfg, SHAPES["train_4k"], "train")
    assert batch["tokens"].shape == (256, 4096)
    n = sum(l.size for l in jax.tree.leaves(state["params"]))
    assert abs(n - cfg.param_count()) / cfg.param_count() < 0.02


def test_decode_specs_cache_rolling_swa():
    cfg = get_config("mixtral-8x7b")             # SWA window 4096
    _, state = input_specs(cfg, SHAPES["long_500k"], "decode")
    (kv,) = [l for l in jax.tree.leaves(state["cache"])
             if l.ndim == 5][:1]
    assert kv.shape[2] == 4096                   # rolling window, not 524288


# ---------------------------------------------------------------------------
# constrain(): no-op without context; correct specs with context
# ---------------------------------------------------------------------------

def test_constrain_noop_without_context():
    from repro.runtime.sharding import constrain
    x = jnp.zeros((4, 8))
    assert constrain(x, "b.") is x


def test_constrain_applies_in_context():
    from repro.runtime.sharding import activation_sharding, constrain
    mesh = jax.make_mesh((1,), ("data",))
    with activation_sharding(mesh, "2d"):
        out = jax.jit(lambda x: constrain(x, "b."))(jnp.zeros((4, 8)))
    assert out.shape == (4, 8)


def test_constrain_conflicting_axes_skipped():
    from repro.runtime.sharding import activation_sharding, constrain
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.zeros((4, 4))
    with activation_sharding(mesh, "2d"):
        # batch and expert dims both want "data" -> constraint skipped
        out = constrain(x, "bd")
        assert out is x


# ---------------------------------------------------------------------------
# serve-mesh accounting: per-shard memory/FLOPs without building the mesh
# ---------------------------------------------------------------------------

def test_serve_cell_per_shard_accounting():
    # importing dryrun sets XLA_FLAGS at module top, but jax is already
    # initialized in the test process so the env write is inert here
    from repro.launch.dryrun import run_serve_cell

    one = run_serve_cell("smollm-360m", mesh_shape=(1, 1), slots=4,
                         max_len=64, smoke=True)
    # a 1-device mesh: per-device == total, everything accounted
    assert one["params_bytes_per_device"] == one["params_bytes"] > 0
    assert one["state_bytes_per_device"] == one["state_bytes"] > 0
    assert 0 < one["kv_pool_bytes"] <= one["state_bytes"]

    two = run_serve_cell("minicpm3-4b", mesh_shape=(1, 2), slots=2,
                         max_len=64, smoke=True)
    # MLA paged pools split their latent dim over 2 model shards
    assert two["kv_pool_bytes_per_device"] * 2 == two["kv_pool_bytes"]
    # column-parallel params shard, row-parallel replicate: strictly
    # between the all-replicated and all-sharded extremes
    assert (two["params_bytes"] // 2
            < two["params_bytes_per_device"] < two["params_bytes"])
    assert two["decode_flops_per_device"] * 2 == two["decode_flops"]
    assert two["mesh_devices"] == 2


def test_serve_shard_factors_mirror_sharding_rules():
    """The pure divisor helpers agree with the real serve shardings: a
    leaf's factor is the model-axis size exactly when the named rule's
    dim divides, else 1 (replication)."""
    from repro.configs.base import get_smoke_config
    from repro.models.api import init_decode_state
    from repro.runtime import sharding as shd

    cfg = get_smoke_config("minicpm3-4b")
    state = jax.eval_shape(lambda: init_decode_state(cfg, 2, 64, kv="paged"))
    factors = {}

    def one(path, leaf):
        name = shd._leaf_name(path)
        factors.setdefault(name, set()).add(
            shd.serve_state_shard_factor(path, leaf.shape, 2))
    jax.tree_util.tree_map_with_path(one, state)
    # MLA latent pools split; control leaves replicate
    assert factors["ckvp"] == {2} and factors["kropep"] == {2}
    assert factors["pos"] == {1} and factors["block_tables"] == {1}
    # msz=1 never shards anything
    def check_one(path, leaf):
        assert shd.serve_state_shard_factor(path, leaf.shape, 1) == 1
    jax.tree_util.tree_map_with_path(check_one, state)


# ---------------------------------------------------------------------------
# the real thing: one cheap cell lowered+compiled on the 16x16 mesh in a
# subprocess (XLA_FLAGS isolation)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=str(REPO))
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads((REPO / "results" / "dryrun" / "pod16x16" /
                      "mamba2-370m__decode_32k.json").read_text())
    assert rec["mesh"]["shape"] == [16, 16]
    t = rec["roofline"]
    assert t["compute_s"] > 0 and t["memory_s"] > 0
    assert rec["hlo_cost"]["flops"] > 0
