import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--concurrency-audit", action="store_true", default=False,
        help="run the whole session under the instrumented lock auditor "
             "and fail it on lock-order cycles or under-lock-callback "
             "violations")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-second integration tests (dry-run subprocess)")
    if config.getoption("--concurrency-audit"):
        from repro.analysis.locks import LockAuditor
        config._lock_auditor = LockAuditor().install()


def pytest_sessionfinish(session, exitstatus):
    aud = getattr(session.config, "_lock_auditor", None)
    if aud is None:
        return
    aud.uninstall()
    rep = aud.report()
    print()
    print(aud.format_report(rep))
    if rep["cycles"] or rep["violations"]:
        print("concurrency audit FAILED: "
              f"{len(rep['cycles'])} cycle(s), "
              f"{len(rep['violations'])} violation(s)")
        session.exitstatus = 1


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
