import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-second integration tests (dry-run subprocess)")


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
