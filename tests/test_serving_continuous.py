"""Continuous-batching serve path: slot isolation, churn, per-slot
eviction, the one-device→host-transfer-per-step rule, per-row decode
positions, registry prefetch, and the serve payload through the pilot.

Model-heavy tests carry @pytest.mark.slow (fast lane skips them); the
per-row attention unit tests and the registry prefetch contract run in the
fast lane.
"""

from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.serving.engine import Request, ServeEngine, admit_length


def _params(cfg):
    from repro.models.api import build_model
    return build_model(cfg).init(jax.random.key(0))


def _req(rid, plen, max_new, vocab=512, seed=None):
    rng = np.random.default_rng(seed if seed is not None else rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
                   max_new_tokens=max_new)


# ---------------------------------------------------------------------------
# per-row decode positions (unit level, fast lane)
# ---------------------------------------------------------------------------

def test_attention_decode_vector_pos_matches_scalar():
    """All rows at the same position: the (B,) pos vector must reproduce the
    scalar-pos decode bit for bit."""
    from repro.models import attention as attn

    cfg = get_smoke_config("smollm-360m")
    p = attn.init_attention(jax.random.key(1), cfg)
    B, T = 3, 32
    cache = attn.init_kv_cache(cfg, B, T)
    x = jax.random.normal(jax.random.key(2), (B, 1, cfg.d_model),
                          jnp.bfloat16)
    out_s, c_s = attn.attention_decode(x, p, cfg, cache, jnp.int32(5))
    out_v, c_v = attn.attention_decode(x, p, cfg, cache,
                                       jnp.full((B,), 5, jnp.int32))
    np.testing.assert_array_equal(np.asarray(out_s, np.float32),
                                  np.asarray(out_v, np.float32))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_attention_decode_ragged_pos_matches_per_row_runs():
    """Ragged positions: row b of a batched decode must equal running that
    row alone at its scalar position — the slot-isolation invariant at the
    attention layer."""
    from repro.models import attention as attn

    cfg = get_smoke_config("smollm-360m")
    p = attn.init_attention(jax.random.key(1), cfg)
    B, T = 3, 32
    cache = {k: jax.random.normal(jax.random.key(3), v.shape, v.dtype) * 0.1
             for k, v in attn.init_kv_cache(cfg, B, T).items()}
    x = jax.random.normal(jax.random.key(2), (B, 1, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.asarray([2, 17, 30], jnp.int32)
    out, new_cache = attn.attention_decode(x, p, cfg, cache, pos)
    for b in range(B):
        cache_b = {k: v[b:b + 1] for k, v in cache.items()}
        out_b, nc_b = attn.attention_decode(x[b:b + 1], p, cfg, cache_b,
                                            pos[b])
        np.testing.assert_array_equal(np.asarray(out[b], np.float32),
                                      np.asarray(out_b[0], np.float32))
        for k in new_cache:
            np.testing.assert_array_equal(
                np.asarray(new_cache[k][b], np.float32),
                np.asarray(nc_b[k][0], np.float32))


def test_decode_state_pos_is_per_slot():
    from repro.models.api import init_decode_state

    st = init_decode_state(get_smoke_config("smollm-360m"), 4, 32)
    assert st["pos"].shape == (4,)


# ---------------------------------------------------------------------------
# engine: slot isolation / churn / eviction (model-level, slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_slot_isolation_mid_decode_admission():
    """Admitting a request mid-decode must leave the other slot's token
    stream IDENTICAL to a solo run — per-slot positions mean rows never
    interact."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)

    solo = ServeEngine(cfg, params, slots=2, max_len=64)
    solo.submit(_req(0, 7, 12, cfg.vocab_size))
    solo.run()
    solo_tokens = tuple(solo.done[0].tokens)

    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    eng.submit(_req(0, 7, 12, cfg.vocab_size))
    for _ in range(5):
        eng.step()                       # request 0 is mid-decode
    eng.submit(_req(1, 13, 9, cfg.vocab_size))
    eng.run()
    assert tuple(eng.done[0].tokens) == solo_tokens
    assert eng.done[1].tokens            # the intruder also completed


@pytest.mark.slow
def test_churn_full_queue_mixed_prompt_lengths():
    """More requests than slots, mixed prompt lengths and budgets: freed
    slots must be refilled immediately (no wave barrier), every request
    completes with exactly 1 + max_new_tokens tokens."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=96)
    lens = [4, 21, 9, 40, 5, 17, 30]
    budgets = [3, 7, 5, 4, 9, 6, 8]
    for i, (pl, mn) in enumerate(zip(lens, budgets)):
        eng.submit(_req(i, pl, mn, cfg.vocab_size))
    stats = eng.run()
    assert stats["completed"] == 7
    for i, mn in enumerate(budgets):
        assert len(eng.done[i].tokens) == mn + 1, (i, eng.done[i].tokens)
    # continuous admission: the whole run needs only ceil(total/2) + ramp
    # steps, far below the wave schedule's sum of per-wave maxima
    assert stats["slot_utilization"] > 0.8, stats
    # device-resident loop contract
    assert stats["d2h_transfers"] == stats["decode_steps"]


@pytest.mark.slow
def test_max_len_eviction_per_slot():
    """A slot whose pos reaches max_len is evicted on its own clock while
    its neighbor keeps decoding, and the freed slot is refilled."""
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    eng.submit(_req(0, 5, 500, cfg.vocab_size))     # bucket 16: evicts at 32
    eng.submit(_req(1, 5, 3, cfg.vocab_size))
    eng.submit(_req(2, 5, 4, cfg.vocab_size))       # refills slot 1
    eng.run()
    assert len(eng.done) == 3
    # prefill token + one per decode position plen..max_len-1
    assert len(eng.done[0].tokens) == 1 + (32 - 16)
    assert len(eng.done[1].tokens) == 4
    assert len(eng.done[2].tokens) == 5


@pytest.mark.slow
def test_prompt_too_long_rejected():
    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError):
        eng.submit(_req(0, 32, 4, cfg.vocab_size))
    assert admit_length(5, 32) == 16


# ---------------------------------------------------------------------------
# the one-transfer-per-step rule
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_single_host_transfer_per_decode_step():
    """The decode loop must perform exactly ONE device→host materialization
    per step (the packed tokens/done array).  Counted by intercepting
    ArrayImpl._value — the funnel for device_get and int()/float() pulls —
    which is what the wave engine's per-slot int(pos) syncs went through."""
    import jax._src.array as jarr

    cfg = get_smoke_config("smollm-360m")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    eng.submit(_req(0, 7, 30, cfg.vocab_size))
    eng.submit(_req(1, 4, 30, cfg.vocab_size))
    eng.step()                 # admissions (prefill argmax pulls) land here

    orig = jarr.ArrayImpl.__dict__["_value"]
    pulls = []
    jarr.ArrayImpl._value = property(lambda self: (pulls.append(1),
                                                   orig.fget(self))[1])
    try:
        before = eng.steps
        for _ in range(6):
            eng.step()
        n_steps = eng.steps - before
    finally:
        jarr.ArrayImpl._value = orig
    assert n_steps == 6
    assert len(pulls) == n_steps, f"{len(pulls)} host pulls in {n_steps} steps"
    assert eng.d2h_transfers == eng.steps


# ---------------------------------------------------------------------------
# registry prefetch (fast lane: noop image compiles in microseconds)
# ---------------------------------------------------------------------------

def test_registry_prefetch_single_flight():
    from repro.core.images import ExecutableRegistry, PayloadImage

    reg = ExecutableRegistry()
    img = PayloadImage(arch="placeholder", shape="none", mode="noop")
    ev = reg.prefetch(img)
    assert ev.wait(timeout=30.0)
    exe = reg.pull(img)
    assert exe.cached                       # the prefetch paid the compile
    assert reg.stats["prefetches"] == 1
    # an already-cached image prefetches to an immediately-set event
    ev2 = reg.prefetch(img)
    assert ev2.is_set()
    assert reg.stats["prefetches"] == 1     # no second background compile


def test_registry_prefetch_concurrent_pull_single_compile():
    """A pull racing a prefetch of the same image must wait on the same
    single-flight compile, not start a second one."""
    from repro.core.images import ExecutableRegistry, PayloadImage

    reg = ExecutableRegistry()
    img = PayloadImage(arch="placeholder", shape="none", mode="noop")
    results = []

    def bind():
        results.append(reg.pull(img))

    ev = reg.prefetch(img)
    t = threading.Thread(target=bind)
    t.start()
    t.join(30.0)
    assert ev.wait(timeout=30.0)
    assert reg.stats["misses"] == 1         # exactly one compile happened


def test_registry_prefetch_race_spawns_one_worker():
    """Concurrent prefetches of the same uncached image must claim the key
    under the lock: one background compile, every caller joins it."""
    from repro.core.images import ExecutableRegistry, PayloadImage

    reg = ExecutableRegistry()
    img = PayloadImage(arch="placeholder", shape="none", mode="noop")
    start = threading.Barrier(4)
    evs = []

    def go():
        start.wait()
        evs.append(reg.prefetch(img))

    threads = [threading.Thread(target=go) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    assert len(evs) == 4
    for ev in evs:
        assert ev.wait(timeout=30.0)
    assert reg.stats["prefetches"] == 1
    assert reg.stats["misses"] == 1


@pytest.mark.slow
def test_prefetch_hint_warms_next_bind():
    """A matched task's prefetch hint overlaps the NEXT image's pull with
    the current payload's run: the follow-up bind is a cache hit."""
    from repro.core.cluster import ClusterSim
    from repro.core.images import PayloadImage
    from repro.core.pilot import PilotConfig

    sim = ClusterSim()
    img1 = PayloadImage("smollm-360m", "smoke", "decode")
    img2 = PayloadImage("mamba2-370m", "smoke", "decode")
    sim.repo.submit(img1, n_steps=3, prefetch_hint=img2)
    sim.repo.submit(img2, n_steps=3)
    (s,) = sim.provision(1)
    pilot = sim.spawn_pilot(s, PilotConfig(max_payloads=3, idle_grace=1.0))
    assert sim.run_until_drained(timeout=300.0)
    sim.join_all(30.0)
    assert sim.registry.stats["prefetches"] == 1
    assert [h["exitcode"] for h in pilot.history] == [0, 0]
    assert pilot.history[0]["prefetch_started"] is True
    # the second bind found its image in the cache (compile overlapped or
    # joined via single-flight — either way the pull was not a fresh miss)
    assert pilot.history[1]["bind_cached"] is True


# ---------------------------------------------------------------------------
# serve as a first-class pilot payload
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_payload_via_pilot():
    """A pilot late-binds an inference SERVER the way it late-binds a train
    step: the request trace rides in the startup spec, and the telemetry
    reports continuous-batching serving stats."""
    from repro.core.cluster import ClusterSim
    from repro.core.images import PayloadImage
    from repro.core.pilot import PilotConfig
    from repro.launch.serve import make_trace

    cfg = get_smoke_config("smollm-360m")
    trace = make_trace(cfg.vocab_size, 5, max_len=64, seed=3)
    sim = ClusterSim()
    tid = sim.repo.submit(
        PayloadImage("smollm-360m", "smoke", "serve"),
        n_steps=500, payload_spec={"trace": trace, "max_len": 64})
    (s,) = sim.provision(1)
    sim.spawn_pilot(s, PilotConfig(max_payloads=1, idle_grace=1.0))
    assert sim.run_until_drained(timeout=300.0)
    sim.join_all(30.0)
    r = sim.repo.result(tid)
    assert r is not None and r.exitcode == 0
    sv = r.telemetry["serve"]
    assert sv["completed"] == 5
    assert sv["d2h_transfers"] == sv["decode_steps"]
    assert 0.0 < sv["slot_utilization"] <= 1.0
    assert len(r.telemetry["tokens"]) == 5
