"""Autoscaler policy contracts + churn-hygiene fixes in the fleet/repo
substrate.

Policy tests drive ``FleetAutoscaler.tick`` directly with a stub fleet, an
injected demand stream and a fake clock — hysteresis, cooldowns, bounds,
scale-to-zero and the no-flap guarantee are all deterministic.  Everything
that spawns real pilots uses noop images (fast lane); the busy-serving
scale-down test builds model engines and carries @pytest.mark.slow.
"""

from __future__ import annotations

import time

import pytest

from repro.core.autoscaler import AutoscalePolicy, FleetAutoscaler
from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable
from repro.core.taskrepo import TaskRepo, TaskResult

NOOP = PayloadImage(arch="placeholder", shape="none", mode="noop")


# ---------------------------------------------------------------------------
# policy (stub fleet, fake clock, injected demand)
# ---------------------------------------------------------------------------

class _StubFleet:
    def __init__(self, n: int = 0):
        self.n = n
        self.draining_n = 0
        self.ups: list[int] = []
        self.downs: list[int] = []

    def size(self):
        return self.n

    def draining(self):
        return self.draining_n

    def scale_up(self, n):
        self.n += n
        self.ups.append(n)
        return [object()] * n

    def scale_down(self, n):
        self.n -= n
        self.downs.append(n)
        return []


def _scaler(fleet, policy, sig, clk):
    return FleetAutoscaler(fleet, None, policy=policy,
                           signals_fn=lambda: dict(sig),
                           clock=lambda: clk[0])


def test_hysteresis_band_holds_and_edges_scale():
    p = AutoscalePolicy(min_pilots=0, max_pilots=8, slots_per_pilot=2,
                        high_water=1.25, low_water=0.5,
                        up_cooldown=1.0, down_cooldown=2.0,
                        down_stable_ticks=3)
    fleet = _StubFleet(2)
    sig = {"demand": 4}                   # util = 4 / (2*2) = 1.0: in band
    clk = [100.0]
    a = _scaler(fleet, p, sig, clk)
    for _ in range(5):
        assert a.tick() is None           # the band absorbs the wiggle
        clk[0] += 1.0
    sig["demand"] = 6                     # util 1.5 > 1.25: grow to fit
    d = a.tick()
    assert d.direction == "up" and d.n == 1 and fleet.n == 3
    assert d.target == 3                  # ceil(6 / 2) — demand-proportional


def test_cooldowns_bound_decision_rate_and_forbid_flaps():
    p = AutoscalePolicy(min_pilots=0, max_pilots=8, slots_per_pilot=1,
                        up_cooldown=1.0, down_cooldown=2.0,
                        down_stable_ticks=1)
    fleet = _StubFleet(1)
    sig = {"demand": 4}
    clk = [10.0]
    a = _scaler(fleet, p, sig, clk)
    assert a.tick().direction == "up"     # 1 -> 4
    sig["demand"] = 8
    assert a.tick() is None               # inside up_cooldown: held
    clk[0] += 0.5
    assert a.tick() is None
    # demand collapses right after the up — a flap candidate.  The down
    # must wait out down_cooldown FROM THE UP, not fire immediately.
    sig["demand"] = 0
    clk[0] += 0.6                         # 1.1s after the up
    assert a.tick() is None
    clk[0] += 1.0                         # 2.1s after the up: now allowed
    d = a.tick()
    assert d.direction == "down" and fleet.n == 0
    assert a.flaps() == 0


def test_oscillating_demand_never_flaps():
    p = AutoscalePolicy(min_pilots=0, max_pilots=4, slots_per_pilot=1,
                        up_cooldown=0.5, down_cooldown=1.0,
                        down_stable_ticks=2)
    fleet = _StubFleet(1)
    sig = {"demand": 0}
    clk = [0.0]
    a = _scaler(fleet, p, sig, clk)
    for i in range(200):                  # demand square-waves every 8 ticks
        sig["demand"] = 4 if (i // 8) % 2 else 0
        a.tick()
        clk[0] += 0.1
    assert a.flaps() == 0
    assert len(a.decisions) >= 2          # it DID scale — just never thrashed


def test_bounds_scale_to_zero_and_burst_from_zero():
    p = AutoscalePolicy(min_pilots=0, max_pilots=3, slots_per_pilot=2,
                        up_cooldown=0.1, down_cooldown=0.1,
                        down_stable_ticks=2)
    fleet = _StubFleet(1)
    sig = {"demand": 100}
    clk = [0.0]
    a = _scaler(fleet, p, sig, clk)
    d = a.tick()
    assert d.target == 3 and fleet.n == 3     # clamped at max_pilots
    # idle: shed everything, but only after down_stable_ticks of low util
    sig["demand"] = 0
    clk[0] += 1.0
    assert a.tick() is None                   # first low tick: hold
    clk[0] += 1.0
    d = a.tick()
    assert d.direction == "down" and d.n == 3 and fleet.n == 0
    # a burst into the empty fleet re-provisions in one jump
    sig["demand"] = 5
    clk[0] += 1.0
    d = a.tick()
    assert d.direction == "up" and d.target == 3 and fleet.n == 3
    assert d.reason.startswith("burst-from-zero")
    assert a.flaps() == 0


def test_kv_pressure_scales_up_inside_the_band():
    p = AutoscalePolicy(min_pilots=0, max_pilots=8, slots_per_pilot=2,
                        up_cooldown=0.1, down_cooldown=0.1,
                        kv_high_water=0.92)
    fleet = _StubFleet(2)
    # util 1.0 — inside the band — but the engines report KV pool pressure
    sig = {"demand": 4, "kv_memory_utilization": 0.97,
           "blocked_admissions": 0}
    clk = [50.0]
    a = _scaler(fleet, p, sig, clk)
    d = a.tick()
    assert d.direction == "up" and d.n == 1 and "kv pressure" in d.reason
    # blocked-admission growth is the other in-band up trigger
    fleet2 = _StubFleet(2)
    sig2 = {"demand": 4, "kv_memory_utilization": 0.5,
            "blocked_admissions": 0}
    b = _scaler(fleet2, p, sig2, clk)
    assert b.tick() is None
    sig2["blocked_admissions"] = 3
    clk[0] += 1.0
    d = b.tick()
    assert d.direction == "up" and "blocked" in d.reason


def test_up_bounded_by_live_pilots_not_effective():
    """A burst while victims are mid-drain: sizing uses effective (live
    minus draining), but the max_pilots bound is on LIVE slices held — the
    fleet must never transiently overdraw the provider quota."""
    p = AutoscalePolicy(min_pilots=0, max_pilots=4, slots_per_pilot=1,
                        up_cooldown=0.1, down_cooldown=0.1)
    fleet = _StubFleet(4)
    fleet.draining_n = 4                  # all four are mid-drain
    sig = {"demand": 8}
    clk = [0.0]
    a = _scaler(fleet, p, sig, clk)
    assert a.tick() is None               # 4 slices still held: no headroom
    assert fleet.ups == []
    fleet.n = 1                           # three victims exited
    fleet.draining_n = 1
    clk[0] += 1.0
    d = a.tick()                          # headroom is max(4) - live(1) = 3
    assert d.direction == "up" and d.n == 3 and fleet.n == 4


def test_blocked_admission_delta_is_per_server():
    """Cumulative per-server counters: server churn (retire / telemetry
    TTL prune / re-announce) must neither fabricate nor mask a delta."""
    p = AutoscalePolicy(min_pilots=0, max_pilots=8, slots_per_pilot=2,
                        up_cooldown=0.1, down_cooldown=0.1)
    fleet = _StubFleet(2)
    sig = {"demand": 4, "kv_memory_utilization": 0.5,   # util 1.0: in band
           "blocked_admissions": 7, "blocked_by_server": {"a": 7}}
    clk = [0.0]
    a = _scaler(fleet, p, sig, clk)
    assert a.tick() is None               # first sight of "a": history
    clk[0] += 1.0                         # unknown, no delta
    sig["blocked_by_server"] = {}         # "a" pruned (stalled server)
    sig["blocked_admissions"] = 0
    assert a.tick() is None               # sum dropped 7: NOT a trigger
    clk[0] += 1.0
    sig["blocked_by_server"] = {"a": 7}   # "a" resumes reporting
    sig["blocked_admissions"] = 7
    assert a.tick() is None               # sum jumped +7 with zero new
    clk[0] += 1.0                         # pressure: still not a trigger
    sig["blocked_by_server"] = {"a": 9}   # genuinely new blocks
    sig["blocked_admissions"] = 9
    d = a.tick()
    assert d is not None and d.direction == "up" and "blocked" in d.reason


def test_min_pilots_floor_is_respected():
    p = AutoscalePolicy(min_pilots=2, max_pilots=6, slots_per_pilot=1,
                        up_cooldown=0.1, down_cooldown=0.1,
                        down_stable_ticks=1)
    fleet = _StubFleet(4)
    sig = {"demand": 0}
    clk = [0.0]
    a = _scaler(fleet, p, sig, clk)
    d = a.tick()
    assert d.direction == "down" and fleet.n == 2     # never below the floor
    clk[0] += 1.0
    assert a.tick() is None


# ---------------------------------------------------------------------------
# churn hygiene: member/registry reaping, heartbeat eviction, drain latch
# ---------------------------------------------------------------------------

def test_fleet_reaps_terminal_pilots_into_bounded_history():
    sim = ClusterSim()
    fleet = sim.spawn_fleet(2, PilotConfig(max_payloads=1, idle_grace=0.2))
    for _ in range(2):
        sim.repo.submit(NOOP, n_steps=1)
    assert sim.run_until_drained(timeout=60.0)
    fleet.join_all(timeout=30.0)
    deadline = time.monotonic() + 10.0
    while fleet.size() > 0 and time.monotonic() < deadline:
        time.sleep(0.02)                  # live() reaps as threads finish
    assert fleet.size() == 0
    assert fleet.members == []            # reaped, not merely terminal
    assert sim.pilots == {}               # ClusterSim registry pruned too
    assert len(fleet.history) == 2 and len(sim.pilot_history) == 2
    for rec in fleet.history:             # state_log survives the reap
        assert rec["state_log"][0] == "created"
        assert rec["state"] in ("terminated", "drained")
        assert rec["payloads_run"] == 1
        assert rec["pilot_seconds"] > 0.0


def test_scale_down_sheds_distinct_idle_victims():
    sim = ClusterSim()
    fleet = sim.spawn_fleet(3, PilotConfig(idle_grace=30.0))
    try:
        v1 = fleet.scale_down(1)
        v2 = fleet.scale_down(1)          # the first victim is mid-drain:
        assert len(v1) == len(v2) == 1    # it must not be picked again
        assert v1[0].pilot_id != v2[0].pilot_id
        v3 = fleet.scale_down(5)          # only one non-draining pilot left
        assert len(v3) == 1
        assert len({p.pilot_id for p in v1 + v2 + v3}) == 3
    finally:
        fleet.drain_all()
        fleet.join_all(30.0)


def test_heartbeat_eviction_on_lease_reap_and_terminate():
    repo = TaskRepo(lease_ttl=0.1, pilot_ttl=60.0)
    repo.heartbeat_pilot("A", 0.01)
    assert repo.stats()["pilots"] == 1
    repo.submit(NOOP)
    task = repo.match({"pilot_id": "A", "labels": {}})
    assert task is not None
    deadline = time.monotonic() + 10.0    # A dies: never renews
    while repo.stats()["leased"] and time.monotonic() < deadline:
        time.sleep(0.02)
    s = repo.stats()
    assert s["queued"] == 1 and s["leased"] == 0
    assert s["pilots"] == 0               # the reaper evicted the ghost
    assert repo.fleet_median_step_time() is None
    # explicit eviction (the pilot terminate path)
    repo.heartbeat_pilot("B", 0.02)
    repo.evict_pilot("B")
    assert repo.stats()["pilots"] == 0


def test_heartbeat_ttl_prunes_silent_pilots():
    repo = TaskRepo(pilot_ttl=0.05)
    repo.heartbeat_pilot("ghost")
    assert repo.stats()["pilots"] == 1
    time.sleep(0.1)
    assert repo.stats()["pilots"] == 0


def test_drain_latch_survives_momentary_empty_window():
    """Bursty arrivals: between staggered submissions the repo is briefly
    queued == leased == 0 — with submissions open, wait_drained must NOT
    return until the submitter seals."""
    repo = TaskRepo()
    assert repo.wait_drained(timeout=0.01)     # legacy: born sealed+empty
    repo.open_submissions()
    assert not repo.wait_drained(timeout=0.05)
    tid = repo.submit(NOOP)
    task = repo.match({"pilot_id": "p", "labels": {}})
    repo.complete(TaskResult(task_id=tid, pilot_id="p", exitcode=0,
                             telemetry={}))
    # empty again — but the submitter has not sealed: this is exactly the
    # early-flip window the latch closes
    assert not repo.wait_drained(timeout=0.05)
    tid2 = repo.submit(NOOP)               # the second burst arrives
    repo.seal()
    assert not repo.wait_drained(timeout=0.05)   # sealed but not empty
    task2 = repo.match({"pilot_id": "p", "labels": {}})
    repo.complete(TaskResult(task_id=tid2, pilot_id="p", exitcode=0,
                             telemetry={}))
    assert repo.wait_drained(timeout=5.0)        # sealed AND empty: drained


def test_proctable_drain_uid_is_sticky_and_uid_scoped():
    table = ProcessTable()
    e1 = table.register(PAYLOAD_UID, "payload:a")
    pe = table.register(PILOT_UID, "pilot")
    assert table.drain_uid(PAYLOAD_UID) == 1
    assert e1.drain.is_set()
    assert not pe.drain.is_set()          # other uids untouched
    assert e1.state == "running"          # drain is graceful, not a kill
    # a payload that registers AFTER the drain request starts drained
    e2 = table.register(PAYLOAD_UID, "payload:b")
    assert e2.drain.is_set()
    # non-pilot signallers get EPERM semantics
    assert table.drain_uid(PAYLOAD_UID, signaller_uid=PAYLOAD_UID) == 0


# ---------------------------------------------------------------------------
# scale-down of a BUSY serving pilot (slow lane: real engines)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_scale_down_busy_serving_pilot_releases_leases():
    """A drained serving pilot must hand its leased requests straight back
    to the pool (release path) — with lease_ttl=600 the TTL can never be
    the requeue mechanism, so completion of the whole trace proves it.
    Back-to-back scale_downs must shed distinct pilots even while the
    first victim is mid-drain."""
    import numpy as np
    from repro.configs.base import get_smoke_config
    from repro.serving.dispatch import FleetDispatcher

    cfg = get_smoke_config("smollm-360m")
    sim = ClusterSim()
    pool = FleetDispatcher(lease_ttl=600.0)
    fleet = sim.spawn_fleet(3, PilotConfig(max_payloads=2, idle_grace=0.3))
    img = PayloadImage("smollm-360m", "smoke", "serve")
    try:
        fleet.submit_servers(img, pool.name, n=3,
                             spec={"slots": 2, "max_len": 64})
        assert pool.wait_servers(3, timeout=300.0)
        rng = np.random.default_rng(0)
        for rid in range(24):
            pool.submit({"rid": rid,
                         "prompt": rng.integers(
                             0, cfg.vocab_size, size=8).tolist(),
                         "max_new_tokens": 40})
        assert pool.wait_completed(3, timeout=120.0)
        (v1,) = fleet.scale_down(1)
        (v2,) = fleet.scale_down(1)       # v1 is mid-drain: must differ
        assert v1.pilot_id != v2.pilot_id
        held = (pool.lease_holders().get(v1.pilot_id, [])
                + pool.lease_holders().get(v2.pilot_id, []))
        pool.seal()
        # the survivor can only finish if the victims RELEASED their leases
        # (immediate requeue) — a lease-TTL wait would blow the timeout
        assert pool.wait_all(timeout=120.0)
        stats = pool.stats()
        assert stats["completed"] == 24 and stats["failed"] == 0
        assert stats["duplicates"] == 0
        if held:                          # victims were busy when drained
            assert stats["replays"] >= 1
        for v in (v1, v2):
            v.join(30.0)
            assert v.state == "drained"
    finally:
        pool.close()
        fleet.drain_all()
        fleet.join_all(30.0)
