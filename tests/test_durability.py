"""Durability substrate for the fault-tolerant fleet: checkpoint overwrite
crash windows, restore dtype validation, and the empty-fleet remesh refusal.

Deliberately hypothesis-free (unlike test_substrate.py, which skips as a
module when hypothesis is absent): these contracts are what the
requeue-on-pilot-failure story leans on and must run in every environment.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.runtime.elastic import (NoViableMeshError, plan_remesh,
                                   viable_data_axis)
from repro.runtime.mesh import MeshSpec


def _tree(s=0):
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) + s,
            "b": jnp.ones((3,), jnp.float32) * (s + 1)}


# ---------------------------------------------------------------------------
# checkpoint overwrite crash window
# ---------------------------------------------------------------------------

def test_ckpt_overwrite_crash_window_recovers(tmp_path, monkeypatch):
    """A crash between 'retire the old step_N aside' and 'rename tmp into
    place' must leave the latest checkpoint restorable: the sweep puts the
    retired (old, complete) dir back, so latest_step never dangles."""
    d = str(tmp_path)
    ck.save(d, 1, _tree(1))
    ck.save(d, 2, _tree(2))
    old = ck.restore(d, 2, jax.eval_shape(lambda: _tree(2)))

    real_rename = os.rename

    def crash_after_retire(src, dst):
        real_rename(src, dst)
        if ck._RETIRED_PREFIX in os.path.basename(dst):
            raise RuntimeError("injected crash mid-overwrite")

    monkeypatch.setattr(os, "rename", crash_after_retire)
    with pytest.raises(RuntimeError, match="injected crash"):
        ck.save(d, 2, _tree(99))          # overwrite dies between renames
    monkeypatch.setattr(os, "rename", real_rename)
    # age the retired dir past the live-writer grace window (the sweep
    # refuses to reinstate a fresh dir that may belong to an in-flight
    # save).  The retire time rides in the NAME — rename preserves mtime,
    # so aging means rewriting the embedded timestamp.
    (retired,) = [f for f in os.listdir(d)
                  if f.startswith(ck._RETIRED_PREFIX)]
    parts = retired[len(ck._RETIRED_PREFIX):].split("_")
    parts[1] = str(int(parts[1]) - 60_000)
    aged = ck._RETIRED_PREFIX + "_".join(parts)
    os.rename(os.path.join(d, retired), os.path.join(d, aged))
    # LATEST still points at step 2 and step 2 still restores — with the
    # OLD (complete, valid) content, not a half-written replacement
    assert ck.latest_step(d) == 2
    got = ck.restore(d, 2, jax.eval_shape(lambda: _tree(2)))
    for a, b in zip(jax.tree.leaves(old), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not [f for f in os.listdir(d) if f.startswith(ck._RETIRED_PREFIX)]
    # the recovered tree saves over cleanly afterwards
    ck.save(d, 2, _tree(7))
    assert ck.latest_step(d) == 2


def test_ckpt_fresh_retired_dir_is_left_for_its_writer(tmp_path, monkeypatch):
    """A retired dir YOUNGER than the grace window may belong to a live
    writer mid-overwrite: the sweep must not reinstate it (that would make
    the writer's rename(tmp, final) collide)."""
    d = str(tmp_path)
    ck.save(d, 1, _tree(1))
    ck.save(d, 2, _tree(2))
    real_rename = os.rename

    def crash_after_retire(src, dst):
        real_rename(src, dst)
        if ck._RETIRED_PREFIX in os.path.basename(dst):
            raise RuntimeError("injected crash mid-overwrite")

    monkeypatch.setattr(os, "rename", crash_after_retire)
    with pytest.raises(RuntimeError):
        ck.save(d, 2, _tree(99))
    monkeypatch.setattr(os, "rename", real_rename)
    # fresh retired dir: step_2 is gone and NOT reinstated yet, so the
    # latest restorable checkpoint is step 1 — stale but valid, never a
    # dangling pointer or a half-written dir
    assert ck.latest_step(d) == 1
    ck.restore(d, 1, jax.eval_shape(lambda: _tree(1)))


def test_ckpt_retired_leftover_is_garbage_collected(tmp_path):
    """Crash AFTER the replacement landed: the retired dir is stale garbage
    and the next sweep removes it without touching the new step."""
    d = str(tmp_path)
    ck.save(d, 3, _tree(3))
    os.makedirs(os.path.join(d, f"{ck._RETIRED_PREFIX}3_999_999"))
    assert ck.latest_step(d) == 3                  # sweep ran
    assert not [f for f in os.listdir(d) if f.startswith(ck._RETIRED_PREFIX)]


def test_ckpt_restore_dtype_mismatch_raises(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, {"w": jnp.ones((2, 2), jnp.float32)})
    like = {"w": jax.ShapeDtypeStruct((2, 2), jnp.bfloat16)}
    with pytest.raises(ValueError, match="dtype"):
        ck.restore(d, 1, like)
    got = ck.restore(d, 1, like, cast=True)        # the explicit opt-in
    assert np.dtype(got["w"].dtype) == np.dtype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                  np.ones((2, 2), np.float32))


# ---------------------------------------------------------------------------
# empty-fleet remesh refusal
# ---------------------------------------------------------------------------

def test_empty_fleet_is_an_explicit_no_viable_mesh():
    """A fleet that lost every pilot must surface NoViableMeshError — not a
    bogus 1-slice plan from viable_data_axis(0, ...) == 1."""
    with pytest.raises(NoViableMeshError):
        viable_data_axis(0, 256)
    with pytest.raises(NoViableMeshError):
        viable_data_axis(-3, 256)
    with pytest.raises(NoViableMeshError):
        plan_remesh(MeshSpec((4, 4), ("data", "model")), 0, 4, 256)
    # NoViableMeshError is a ValueError: existing callers' handling holds
    with pytest.raises(ValueError):
        plan_remesh(None, 0, 16, 256)
    # the boundary above the refusal: one live slice still plans
    plan = plan_remesh(None, 1, 4, 256)
    assert plan.new_mesh.shape == (1, 4)
    assert viable_data_axis(1, 256) == 1
