"""Speculative decoding: draft-and-verify multi-token steps with paged
rollback.

The load-bearing property is BITWISE EQUALITY: greedy draft-and-verify
commits exactly the tokens sequential greedy decode would produce, for any
draft model — the draft only changes how many positions each step
advances, never which tokens are committed.  On top of that: the k-query
verify kernel vs its jnp oracle, rejected-suffix rollback never touching a
shared prefix block (copy-on-write property), the one-transfer-per-step
contract surviving the multi-token return (asserted by intercepting
device->host pulls at the ArrayImpl layer), cancel-mid-verify releasing
every draft-extended block (leak/underflow guard), SSM/SWA archs falling
back non-speculative with a recorded reason, and the fleet path replaying
requeued requests bitwise with speculation on.

Pure-function tests run in the fast lane; everything that builds a full
model engine carries @pytest.mark.slow.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.serving.engine import Request, ServeEngine, spec_ineligible_reason


def _params(cfg):
    from repro.models.api import build_model
    return build_model(cfg).init(jax.random.key(0))


def _reqs(n=4, vocab=500):
    rng = np.random.default_rng(0)
    lens = [7, 20, 3, 31, 12, 25]
    buds = [9, 13, 17, 5, 11, 7]
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab, size=lens[i % 6]).astype(
                        np.int32),
                    max_new_tokens=buds[i % 6]) for i in range(n)]


# ---------------------------------------------------------------------------
# fast lane: eligibility gating + the verify kernel vs its oracle
# ---------------------------------------------------------------------------

def test_spec_ineligible_reasons():
    gqa = get_smoke_config("smollm-360m")
    assert spec_ineligible_reason(gqa, "paged") is None
    assert "paged" in spec_ineligible_reason(gqa, "dense")
    assert "SSM" in spec_ineligible_reason(
        get_smoke_config("mamba2-370m"), "paged")
    assert "SSM" in spec_ineligible_reason(
        get_smoke_config("jamba-v0.1-52b"), "paged")
    assert "SWA" in spec_ineligible_reason(
        get_smoke_config("mixtral-8x7b"), "paged")
    assert "enc-dec" in spec_ineligible_reason(
        get_smoke_config("whisper-small"), "paged")


@pytest.mark.parametrize("B,S,H,K,Dh,bs,mb", [
    (2, 5, 4, 2, 16, 8, 4),
    (3, 3, 4, 4, 8, 16, 2),
    (1, 5, 8, 1, 32, 8, 3),               # MQA-style grouping
])
def test_paged_verify_kernel_matches_ref(B, S, H, K, Dh, bs, mb):
    from repro.kernels.paged_attention.ops import paged_verify_attention
    from repro.kernels.paged_attention.ref import paged_verify_attention_ref

    rng = np.random.default_rng(0)
    nb = B * mb + 1
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, K, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, K, Dh)), jnp.float32)
    tables = jnp.asarray(
        1 + np.arange(B * mb).reshape(B, mb), jnp.int32)
    off = jnp.asarray(rng.integers(0, mb * bs - S, size=(B,)), jnp.int32)
    out = paged_verify_attention(q, kp, vp, tables, off, interpret=True)
    ref = paged_verify_attention_ref(q, kp, vp, tables, off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=2e-2)


def test_paged_verify_kernel_overflow_positions_are_safe():
    """Query positions past the table's reach (off + s >= mb*bs) must not
    crash or poison finite rows — acceptance clamps them away, but the
    kernel still computes them."""
    from repro.kernels.paged_attention.ops import paged_verify_attention
    from repro.kernels.paged_attention.ref import paged_verify_attention_ref

    rng = np.random.default_rng(1)
    B, S, H, K, Dh, bs, mb = 3, 5, 4, 2, 16, 8, 4
    nb = B * mb + 1
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, K, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, K, Dh)), jnp.float32)
    tables = jnp.asarray(1 + np.arange(B * mb).reshape(B, mb), jnp.int32)
    off = jnp.asarray([mb * bs - 2, mb * bs - 1, mb * bs - 3], jnp.int32)
    out = paged_verify_attention(q, kp, vp, tables, off, interpret=True)
    ref = paged_verify_attention_ref(q, kp, vp, tables, off)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=2e-2)


# ---------------------------------------------------------------------------
# bitwise equality with sequential greedy decode (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-360m", "minicpm3-4b"])
def test_spec_tokens_bitwise_equal_off(arch):
    """Self-draft (acceptance ~1) and a cold random draft (acceptance ~0)
    both commit exactly the spec="off" greedy tokens — per arch family
    (dense GQA and MLA latent attention)."""
    cfg = get_smoke_config(arch)
    from repro.models.api import build_model
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    base = ServeEngine(cfg, params, slots=3, max_len=64, bundle=bundle)
    for r in _reqs(5):
        base.submit(r)
    base.run()
    for draft_cfg in (None, get_smoke_config(arch)):
        eng = ServeEngine(cfg, params, slots=3, max_len=64, bundle=bundle,
                          spec="draft", spec_k=4, draft_cfg=draft_cfg)
        assert eng.spec == "draft", eng.spec_fallback_reason
        for r in _reqs(5):
            eng.submit(r)
        stats = eng.run()
        for rid in range(5):
            assert eng.done[rid].tokens == base.done[rid].tokens, rid
        assert stats["d2h_transfers"] == stats["decode_steps"]
        if draft_cfg is None:              # self-draft: acceptance is high
            assert stats["acceptance_rate"] > 0.5
            assert stats["tokens_per_step"] > 1.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mamba2-370m", "mixtral-8x7b"])
def test_spec_falls_back_on_ssm_swa(arch):
    """Archs whose per-token state cannot roll back serve non-speculatively
    with a recorded reason — and their tokens still match spec="off"."""
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64,
                      spec="draft", spec_k=4)
    assert eng.spec == "off"
    assert eng.spec_fallback_reason is not None
    base = ServeEngine(cfg, params, slots=2, max_len=64)
    for r in _reqs(3):
        eng.submit(r)
    for r in _reqs(3):
        base.submit(r)
    stats = eng.run()
    base.run()
    for rid in range(3):
        assert eng.done[rid].tokens == base.done[rid].tokens
    assert stats["spec"] == "off"
    assert stats["spec_fallback_reason"] == eng.spec_fallback_reason


# ---------------------------------------------------------------------------
# rollback never corrupts a shared prefix (COW property, slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_spec_rollback_never_corrupts_shared_prefix():
    """Two slots share a prompt-prefix block and decode speculatively; the
    draft/verify frontier extensions and every rejected-suffix rollback
    must leave the shared block's pool contents bitwise untouched, in the
    TARGET pools and the shadow DRAFT pools alike — and refcounts must
    balance back to prefix-only pins."""
    cfg = get_smoke_config("smollm-360m")
    from repro.models.api import build_model
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, bundle=bundle,
                      spec="draft", spec_k=4)
    assert eng.spec == "draft"
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, 500, size=30).astype(np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=12))
    eng.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=12))
    eng._admit()
    shared = set(eng._slot_blocks[0]) & set(eng._slot_blocks[1])
    assert shared, "prompts must share a prefix block"
    ids = sorted(shared)
    snap_t = [{k: np.asarray(leaf[k][:, ids]) for k in ("kp", "vp")}
              for leaf in eng.state["cache"]]
    snap_d = [{k: np.asarray(leaf[k][:, ids]) for k in ("kp", "vp")}
              for leaf in eng._draft_cache]
    eng.run()
    assert len(eng.done) == 2
    assert eng.done[0].tokens == eng.done[1].tokens   # same prompt, greedy
    for leaf, snap in zip(eng.state["cache"], snap_t):
        for k in ("kp", "vp"):
            np.testing.assert_array_equal(np.asarray(leaf[k][:, ids]),
                                          snap[k])
    for leaf, snap in zip(eng._draft_cache, snap_d):
        for k in ("kp", "vp"):
            np.testing.assert_array_equal(np.asarray(leaf[k][:, ids]),
                                          snap[k])
    # refcount balance: only prefix-cache pins remain; flushing them
    # returns the pool to empty (any leak or double-free shows up here)
    assert eng.allocator.allocated_blocks == len(eng.prefix._map)
    eng.prefix.evict_unreferenced(eng.allocator.capacity_blocks)
    assert eng.allocator.allocated_blocks == 0


# ---------------------------------------------------------------------------
# one transfer per step, even with k+1 tokens riding it (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_spec_one_transfer_per_step():
    """The packed (k+3, slots) verify return is the ONLY device->host pull
    per decode step: intercept ArrayImpl materialization and count."""
    import jax._src.array as jarr

    cfg = get_smoke_config("smollm-360m")
    from repro.models.api import build_model
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, bundle=bundle,
                      spec="draft", spec_k=4)
    assert eng.spec == "draft"
    for i in range(2):                     # one admission wave, equal budget
        eng.submit(Request(rid=i,
                           prompt=(np.arange(9) + 3 * i + 1).astype(np.int32),
                           max_new_tokens=10))
    eng.step()                             # admissions + first decode step
    pulls = []
    orig = jarr.ArrayImpl.__dict__["_value"]

    def counting(self):
        pulls.append(1)
        return orig.fget(self)

    jarr.ArrayImpl._value = property(counting)
    try:
        steps = 0
        while eng._live:
            eng.step()
            steps += 1
    finally:
        jarr.ArrayImpl._value = orig
    assert steps > 0
    assert len(pulls) == steps, (len(pulls), steps)
    assert eng.d2h_transfers == eng.steps


# ---------------------------------------------------------------------------
# cancel-mid-verify: draft-extended blocks release exactly once (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cancel_mid_verify_releases_draft_extended_blocks():
    """Churn loop: admit, speculate a few steps (the verify frontier is now
    up to k past the committed one in both pools), cancel mid-flight,
    repeat.  Every block must come back exactly once — the allocator
    raises on double-free, and anything leaked shows up as a nonzero
    residue after flushing the prefix pins."""
    cfg = get_smoke_config("smollm-360m")
    from repro.models.api import build_model
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, bundle=bundle,
                      spec="draft", spec_k=4)
    assert eng.spec == "draft"
    rng = np.random.default_rng(3)
    rid = 0
    for round_ in range(4):
        for _ in range(2):
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(1, 500, size=17).astype(np.int32),
                max_new_tokens=20))
            rid += 1
        eng.step()                         # admit + first speculative step
        eng.step()                         # mid-verify state on device
        for r in (rid - 2, rid - 1):
            if r in eng._live:
                assert eng.cancel(r) is not None
        eng.done.clear()
        assert not eng._live
        # only prefix pins may remain allocated between rounds
        assert eng.allocator.allocated_blocks == len(eng.prefix._map)
    eng.prefix.evict_unreferenced(eng.allocator.capacity_blocks)
    assert eng.allocator.allocated_blocks == 0


# ---------------------------------------------------------------------------
# the fleet path: kill a pilot with speculation on (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_requeue_replays_bitwise_with_spec_on():
    """Kill 1 of 3 speculative serving pilots mid-trace: every request
    completes exactly once, and the tokens match both a no-failure
    speculative run AND a non-speculative fleet run bitwise (the image's
    fixed draft seed makes every server draft identically)."""
    from repro.core.images import ExecutableRegistry
    from repro.launch.serve import serve_fleet

    registry = ExecutableRegistry()
    plain = serve_fleet("smollm-360m", 10, 3, slots=2, max_len=64,
                        lease_ttl=0.5, registry=registry)
    ok = serve_fleet("smollm-360m", 10, 3, slots=2, max_len=64,
                     lease_ttl=0.5, registry=registry, draft="self")
    failed = serve_fleet("smollm-360m", 10, 3, slots=2, max_len=64,
                         fail_at=2, lease_ttl=0.5, registry=registry,
                         draft="self")
    assert ok["completed"] == 10 and ok["replays"] == 0
    assert ok["spec_servers"] == 3
    assert ok["acceptance_rate"] > 0.0
    assert failed["completed"] == 10
    assert len(failed["failed_pilots"]) == 1
    assert sorted(failed["results"]) == list(range(10))
    assert failed["results"] == ok["results"] == plain["results"]
    assert failed["replays"] >= 1
