"""TaskRepo — the overlay task repository (HTCondor schedd analogue).

Pilots fetch payloads by *matchmaking*: a pilot advertises its slice
(devices, mesh shape, memory, labels) and the repo returns the
highest-priority queued task whose requirements match (ClassAd-style
predicates over the pilot ad).  Tasks are *leased*, not popped: a pilot must
heartbeat the lease or it expires and the task is re-queued — the
at-least-once delivery that makes dead pilots harmless (fault tolerance at
1000-node scale).  First completion wins: duplicate results from speculative
re-execution are dropped.

Event-driven control plane (this module is its hub):

* ``match_wait(pilot_ad, timeout)`` blocks an idle pilot on a
  ``threading.Condition`` instead of a sleep loop; ``submit``/``release``/
  lease expiry notify all waiters, so a new task wakes pilots in
  microseconds and an idle fleet burns zero CPU.
* Matchmaking is *indexed*: unconstrained tasks live in one priority heap,
  tasks with ``require_labels`` (equality constraints) are bucketed per
  label-set, and only tasks with an opaque predicate need evaluation — a
  match costs O(log n + predicates checked), not a full queue scan.
* Lease expiry is a deadline heap serviced by the shared
  :class:`~repro.core.timerwheel.TimerWheel` (one repo-owned timer), not a
  side effect piggybacked on every ``match`` call.
* ``wait_drained(timeout)`` blocks on a drain event that flips whenever
  queued == leased == 0 — ``ClusterSim.run_until_drained`` no longer polls.
  A bursty submitter calls ``open_submissions()`` before its first submit
  and ``seal()`` after its last: while open, a momentary
  queued == leased == 0 window between staggered submissions does NOT flip
  the drain event (the same latch semantics as the fleet pool's ``seal``).
  A repo that never opens behaves exactly as before (sealed from birth).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable

from repro.analysis.locks import (
    RANK_REPO,
    audit_callback,
    make_condition,
    make_lock,
)
from repro.core.timerwheel import TimerWheel, shared_wheel

Predicate = Callable[[dict], bool]


@dataclasses.dataclass
class BackoffPolicy:
    """Exponential backoff with deterministic jitter for failure requeue.

    A payload that crashes instantly used to hot-loop through the fleet:
    release(failed=True) / lease expiry re-enqueued it with zero delay,
    so the very next match handed it straight back.  The delay doubles
    per attempt up to ``cap`` and is jittered by a hash of
    ``(task_id, attempts)`` — deterministic (replayable runs stay
    replayable) but de-correlated across tasks, so a cohort of requests
    requeued by one pilot death does not re-land as one block on the
    next victim.  ``base <= 0`` disables backoff entirely (the legacy
    immediate-requeue behavior)."""
    base: float = 0.05             # first-failure delay (seconds)
    cap: float = 2.0               # delay ceiling
    jitter: float = 0.5            # +/- fraction around the nominal delay

    def delay(self, task_id: int, attempts: int) -> float:
        if self.base <= 0:
            return 0.0
        nominal = min(self.cap, self.base * (2.0 ** max(0, attempts - 1)))
        # Knuth multiplicative hash: stable across runs, unlike hash()
        frac = ((task_id * 2654435761 + attempts * 40503) % 4096) / 4096.0
        return nominal * (1.0 - self.jitter + 2.0 * self.jitter * frac)


@dataclasses.dataclass
class PayloadTask:
    task_id: int
    image: Any                          # PayloadImage (core.images)
    requirements: Predicate | None = None
    require_labels: dict | None = None  # equality constraints, indexable
    priority: int = 0
    n_steps: int = 20
    max_wall: float = 120.0             # seconds
    input_files: dict[str, bytes] = dataclasses.field(default_factory=dict)
    env: dict = dataclasses.field(default_factory=dict)
    resume: dict = dataclasses.field(default_factory=dict)  # ckpt info
    # extra JSON-able fields merged into the startup spec the pilot
    # publishes — e.g. a serve payload's request trace / engine geometry
    payload_spec: dict = dataclasses.field(default_factory=dict)
    # hint: the image a follow-up task will need; the pilot prefetches it
    # (background compile) while THIS payload runs, so the next bind is warm
    prefetch_hint: Any = None
    attempts: int = 0
    max_attempts: int = 3
    # earliest monotonic time this task may be matched again — stamped by
    # the failure-requeue backoff; 0.0 == immediately eligible
    not_before: float = 0.0


@dataclasses.dataclass
class Lease:
    task: PayloadTask
    pilot_id: str
    expires: float


@dataclasses.dataclass
class TaskResult:
    task_id: int
    pilot_id: str
    exitcode: int
    telemetry: dict
    outputs: dict[str, bytes] = dataclasses.field(default_factory=dict)


class _TaskHeap:
    """Priority heap of queued tasks: highest priority first, FIFO within a
    priority level.  Ordered by task_id (submission order), not a per-push
    sequence — a task re-queued after a predicate rejection or a lease
    expiry keeps its place instead of starving behind newer tasks."""

    __slots__ = ("_heap",)

    def __init__(self):
        self._heap: list[tuple[int, int, PayloadTask]] = []

    def push(self, task: PayloadTask):
        heapq.heappush(self._heap, (-task.priority, task.task_id, task))

    def peek(self) -> PayloadTask | None:
        return self._heap[0][2] if self._heap else None

    def pop(self) -> PayloadTask:
        return heapq.heappop(self._heap)[2]

    def __len__(self):
        return len(self._heap)

    def __bool__(self):
        return bool(self._heap)


class TaskRepo:
    def __init__(self, *, lease_ttl: float = 10.0, wheel: TimerWheel | None = None,
                 pilot_ttl: float | None = None,
                 backoff: BackoffPolicy | None = None,
                 on_expired: Callable[[PayloadTask, str], str] | None = None):
        self._lock = make_lock("taskrepo.repo", rank=RANK_REPO)
        self._cond = make_condition(self._lock)
        self._ids = itertools.count(1)
        self._open = _TaskHeap()                      # no constraints
        self._by_labels: dict[frozenset, _TaskHeap] = {}   # equality-indexed
        self._pred = _TaskHeap()                      # opaque predicates
        self._leases: dict[int, Lease] = {}
        self._deadlines: list[tuple[float, int]] = []  # (expires, task_id)
        self._reap_timer = None
        # backoff-deferred tasks: (not_before, task_id, task) min-heap.  A
        # deferred task is QUEUED (counts toward drain / demand) but not
        # matchable until its stamp passes — a failing task waits out its
        # backoff in here without ever blocking healthy matches
        self._deferred: list[tuple[float, int, PayloadTask]] = []
        self._defer_timer = None
        self.backoff = backoff or BackoffPolicy(base=0.0)   # default: legacy
        # consulted (OUTSIDE the repo lock) when a lease expires: returns
        # "requeue" (default) or "drop" (settle failed — e.g. the fleet
        # dispatcher quarantining a poison request).  Death-event hook for
        # blast-radius accounting at a higher layer.
        self.on_expired = on_expired
        self._results: dict[int, TaskResult] = {}
        self._failed: dict[int, PayloadTask] = {}
        self._pilot_heartbeats: dict[str, float] = {}
        self._step_times: dict[str, float] = {}     # pilot_id -> EWMA
        self.lease_ttl = lease_ttl
        # a pilot whose heartbeat is older than this is presumed gone; its
        # entry is evicted instead of accumulating forever under scale churn
        self.pilot_ttl = (pilot_ttl if pilot_ttl is not None
                          else max(3.0 * lease_ttl, 3.0))
        self._wheel = wheel or shared_wheel()
        self._sealed = True          # legacy behavior: drain flips on empty
        self._drained = threading.Event()
        self._drained.set()                           # empty repo is drained
        # observability for benchmarks: match cost + scheduler wakeups
        self.match_latencies: deque[float] = deque(maxlen=8192)
        self.idle_wakeups = 0                         # woke, found no match
        self.notifies = 0

    # ---- internal: queue index ----------------------------------------------

    def _n_queued(self) -> int:
        return (len(self._open) + len(self._pred) + len(self._deferred)
                + sum(len(h) for h in self._by_labels.values()))

    def _enqueue(self, task: PayloadTask):
        """Route a task to its index bucket.  Caller holds the lock.
        A task whose backoff stamp has not passed parks in the deferred
        heap instead; the defer timer re-routes it when eligible."""
        if task.not_before > time.monotonic():
            heapq.heappush(self._deferred,
                           (task.not_before, task.task_id, task))
            self._drained.clear()
            self._arm_defer_timer(task.not_before)
            return
        if task.requirements is not None:
            self._pred.push(task)
        elif task.require_labels:
            key = frozenset(task.require_labels.items())
            self._by_labels.setdefault(key, _TaskHeap()).push(task)
        else:
            self._open.push(task)
        self._drained.clear()
        self.notifies += 1
        self._cond.notify_all()

    def _arm_defer_timer(self, when: float):
        """Caller holds the lock."""
        if self._defer_timer is None or self._defer_timer.deadline > when:
            if self._defer_timer is not None:
                self._defer_timer.cancel()
            self._defer_timer = self._wheel.call_at(
                when, self._on_defer_timer, name="taskrepo-defer")

    def _on_defer_timer(self):
        """Move every deferral whose stamp has passed back into the match
        index (waking parked pilots), then re-arm for the next one."""
        now = time.monotonic()
        with self._lock:
            self._defer_timer = None
            while self._deferred and self._deferred[0][0] <= now:
                _, _, task = heapq.heappop(self._deferred)
                task.not_before = 0.0
                self._enqueue(task)
            if self._deferred:
                self._arm_defer_timer(self._deferred[0][0])

    def _update_drained(self):
        """Caller holds the lock."""
        if self._sealed and self._n_queued() == 0 and not self._leases:
            self._drained.set()
        else:
            self._drained.clear()

    # ---- submissions-open latch ----------------------------------------------

    def open_submissions(self):
        """Declare that more submissions are coming: ``wait_drained`` must
        not return during a momentary queued == leased == 0 window between
        staggered submissions (bursty arrivals).  Pair with :meth:`seal`."""
        with self._lock:
            self._sealed = False
            self._drained.clear()

    def seal(self):
        """The submitter is done: drain completes the instant the repo is
        empty (and immediately, if it already is)."""
        with self._lock:
            self._sealed = True
            self._update_drained()

    @property
    def sealed(self) -> bool:
        with self._lock:
            return self._sealed

    # ---- submission ---------------------------------------------------------

    def submit(self, image, **kw) -> int:
        with self._lock:
            tid = next(self._ids)
            self._enqueue(PayloadTask(task_id=tid, image=image, **kw))
            return tid

    # ---- matchmaking (step (b)) ---------------------------------------------

    def _try_match(self, pilot_ad: dict) -> PayloadTask | None:
        """Best matching task across the index buckets.  Caller holds lock.

        Candidates: head of the open heap (O(1)), heads of label buckets
        satisfied by the pilot's labels (O(#distinct label-sets)), and the
        best matching predicate task (pops until a predicate passes,
        non-matching entries are pushed back — O(k log n) for k checked).
        """
        t0 = time.perf_counter()
        labels = pilot_ad.get("labels") or {}
        # lazy tombstone purge: a queued copy of a task whose RESULT has
        # already landed (a hedged duplicate settled by first-completion-
        # wins, or a stale requeue racing a completion) must never be
        # leased again — it would win every future match (lowest task_id)
        # and replay settled work forever
        while ((h := self._open.peek()) is not None
               and h.task_id in self._results):
            self._open.pop()
        for key in [k for k, hh in self._by_labels.items()
                    if hh and hh.peek().task_id in self._results]:
            hh = self._by_labels[key]
            while hh and hh.peek().task_id in self._results:
                hh.pop()
            if not hh:
                del self._by_labels[key]
        best: tuple[tuple[int, int], Callable[[], PayloadTask]] | None = None

        def consider(task: PayloadTask, take: Callable[[], PayloadTask]):
            nonlocal best
            rank = (-task.priority, task.task_id)      # FIFO within priority
            if best is None or rank < best[0]:
                best = (rank, take)

        head = self._open.peek()
        if head is not None:
            consider(head, self._open.pop)
        for key, h in self._by_labels.items():
            if h and all(labels.get(k) == v for k, v in key):
                def take_label(h=h, key=key):
                    t = h.pop()
                    if not h:             # drop drained buckets so matches
                        del self._by_labels[key]   # stay O(active label-sets)
                    return t
                consider(h.peek(), take_label)
        # predicate bucket: pop in priority order until one matches
        rejected = []
        while self._pred:
            cand = self._pred.peek()
            if best is not None and (-cand.priority, cand.task_id) >= best[0]:
                break                     # can't beat the indexed candidate
            cand = self._pred.pop()
            if cand.task_id in self._results:
                continue                  # tombstone: drop, don't push back
            try:
                # a task may carry BOTH label constraints and a predicate
                ok = (not cand.require_labels
                      or all(labels.get(k) == v
                             for k, v in cand.require_labels.items())) \
                    and cand.requirements(pilot_ad)
            except Exception:             # noqa: BLE001 — bad predicate ≠ crash
                ok = False
            if ok:
                consider(cand, lambda c=cand: c)
                break
            rejected.append(cand)
        for r in rejected:
            self._pred.push(r)

        if best is None:
            return None
        task = best[1]()
        task.attempts += 1
        self._leases[task.task_id] = Lease(
            task=task, pilot_id=pilot_ad["pilot_id"],
            expires=time.monotonic() + self.lease_ttl)
        self._push_deadline(task.task_id, self._leases[task.task_id].expires)
        self.match_latencies.append(time.perf_counter() - t0)
        return task

    def match(self, pilot_ad: dict) -> PayloadTask | None:
        """Lease the best matching task for this pilot ad, or None."""
        with self._lock:
            return self._try_match(pilot_ad)

    def match_wait(self, pilot_ad: dict, timeout: float | None = None,
                   cancel: Callable[[], bool] | None = None
                   ) -> PayloadTask | None:
        """Lease the best matching task, blocking until one appears.

        The pilot parks on the repo condition; ``submit``/``release``/lease
        expiry wake it.  Returns None on timeout or when ``cancel()`` turns
        true (drain/failure injection — the caller kicks the condition via
        :meth:`kick`).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        woke = False
        with self._cond:
            while True:
                if cancel is not None and cancel():
                    return None
                task = self._try_match(pilot_ad)
                if task is not None:
                    return task
                if woke:                           # woke up, still nothing
                    self.idle_wakeups += 1
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cond.wait(timeout=remaining)
                woke = True

    def kick(self):
        """Wake all parked pilots so they re-check their cancel conditions."""
        with self._lock:
            self._cond.notify_all()

    def renew(self, task_id: int, pilot_id: str) -> bool:
        with self._lock:
            lease = self._leases.get(task_id)
            if lease is None or lease.pilot_id != pilot_id:
                return False
            lease.expires = time.monotonic() + self.lease_ttl
            self._push_deadline(task_id, lease.expires)
            return True

    def heartbeat_pilot(self, pilot_id: str, step_time: float | None = None):
        with self._lock:
            self._pilot_heartbeats[pilot_id] = time.monotonic()
            if step_time is not None:
                prev = self._step_times.get(pilot_id, step_time)
                self._step_times[pilot_id] = 0.7 * prev + 0.3 * step_time

    def evict_pilot(self, pilot_id: str):
        """Forget a pilot's liveness/telemetry state.  Called by a pilot on
        its own terminate path and by the lease reaper when a lease expires
        (no renewals == the pilot is gone); without eviction the heartbeat
        map grows one entry per pilot EVER seen across scale churn."""
        with self._lock:
            self._pilot_heartbeats.pop(pilot_id, None)
            self._step_times.pop(pilot_id, None)

    def _prune_stale_pilots(self, now: float):
        """Caller holds the lock.  Drops pilots silent for > pilot_ttl —
        the backstop for pilots that die without a lease to reap."""
        cutoff = now - self.pilot_ttl
        for pid in [p for p, t in self._pilot_heartbeats.items()
                    if t < cutoff]:
            del self._pilot_heartbeats[pid]
            self._step_times.pop(pid, None)

    def fleet_median_step_time(self) -> float | None:
        with self._lock:
            vals = sorted(self._step_times.values())
        if not vals:
            return None
        return vals[len(vals) // 2]

    # ---- completion (step (e)): first-wins ----------------------------------

    def complete(self, result: TaskResult) -> bool:
        """Returns True if this result was accepted (first completion wins;
        speculative duplicates are dropped).  Non-zero exits keep their lease
        — the pilot follows up with release(task, failed=True) to retry/fail,
        so the repo never looks transiently drained between the two calls."""
        with self._lock:
            if result.task_id in self._results:
                self._leases.pop(result.task_id, None)
                self._update_drained()
                return False                       # speculative duplicate
            if result.exitcode == 0:
                self._leases.pop(result.task_id, None)
                self._results[result.task_id] = result
                self._update_drained()
                return True
            return False

    def release(self, task: PayloadTask, *, failed: bool = False,
                pilot_id: str | None = None, defer_s: float | None = None):
        """Give a leased task back (pilot draining, or payload failure).

        Racing the lease reaper is safe: if the lease is already gone the
        reaper requeued the task (or a result landed) and enqueueing it
        AGAIN here would duplicate it — the release becomes a no-op.  Pass
        ``pilot_id`` to also guard against the task having been re-leased
        to someone else in the meantime (their lease must survive).

        A FAILED release backs off before re-matching (``self.backoff``):
        a crashing payload must not hot-loop through the fleet.  Graceful
        releases requeue immediately (drain latency matters), unless the
        caller paces them explicitly with ``defer_s``."""
        with self._lock:
            lease = self._leases.get(task.task_id)
            if (pilot_id is not None and lease is not None
                    and lease.pilot_id != pilot_id):
                return                     # someone else's lease now
            if task.task_id in self._results:
                self._leases.pop(task.task_id, None)
                self._update_drained()
                return
            if lease is None:              # expired: the reaper handled it
                self._update_drained()
                return
            del self._leases[task.task_id]
            self._prune_stale_pilots(time.monotonic())
            if failed and task.attempts >= task.max_attempts:
                self._failed[task.task_id] = task
                self._update_drained()
                return
            if failed:
                task.not_before = (time.monotonic()
                                   + self.backoff.delay(task.task_id,
                                                        task.attempts))
            elif defer_s is not None:
                task.not_before = time.monotonic() + defer_s
            self._enqueue(task)

    # ---- lease reaping: deadline heap + repo-owned timer ---------------------

    def _push_deadline(self, task_id: int, expires: float):
        """Caller holds the lock.  Entries are lazy — renewals push a fresh
        tuple and stale ones are discarded when popped."""
        heapq.heappush(self._deadlines, (expires, task_id))
        self._arm_reap_timer(expires)

    def _arm_reap_timer(self, expires: float):
        """Caller holds the lock."""
        if self._reap_timer is None or self._reap_timer.deadline > expires:
            if self._reap_timer is not None:
                self._reap_timer.cancel()
            self._reap_timer = self._wheel.call_at(expires, self._on_reap_timer,
                                                   name="taskrepo-lease-reaper")

    def _on_reap_timer(self):
        with self._lock:
            self._reap_timer = None
        self.reap_leases()

    def reap_leases(self) -> int:
        now = time.monotonic()
        with self._lock:
            expired: list[tuple[PayloadTask, str]] = []
            while self._deadlines and self._deadlines[0][0] <= now:
                _, tid = heapq.heappop(self._deadlines)
                lease = self._leases.get(tid)
                if lease is None or lease.expires > now:
                    continue                       # stale entry (renewed/done)
                del self._leases[tid]
                expired.append((lease.task, lease.pilot_id))
                # no renewals for a whole TTL: the holder is presumed dead —
                # evict its heartbeat so the live-pilot signal and the
                # straggler median never count a ghost
                self._pilot_heartbeats.pop(lease.pilot_id, None)
                self._step_times.pop(lease.pilot_id, None)
            self._prune_stale_pilots(now)
        # the death-event hook runs OUTSIDE the repo lock: the fleet
        # dispatcher's blast-radius accounting takes its own pool lock
        # there, and pool->repo is the established lock order everywhere
        # else (fetch/complete/release all call in holding the pool lock)
        dispositions: dict[int, str] = {}
        if self.on_expired is not None and expired:
            audit_callback("taskrepo:on_expired")
            for task, pid in expired:
                try:
                    dispositions[task.task_id] = self.on_expired(task, pid)
                except Exception:        # noqa: BLE001 — a broken hook must
                    pass                 # not disable lease recovery
        with self._lock:
            for task, pid in expired:
                if task.task_id in self._results:
                    continue
                if dispositions.get(task.task_id) == "drop":
                    # the hook settled it (e.g. poison quarantine): record
                    # as failed so drain accounting and failed_tasks() agree
                    self._failed[task.task_id] = task
                elif task.attempts >= task.max_attempts:
                    # the dispatch budget is spent: settle as failed instead
                    # of cycling lease→expire→requeue forever (a release
                    # (failed=True) that races the expiry would otherwise
                    # never reach the _failed state)
                    self._failed[task.task_id] = task
                else:
                    # an expiry IS a delivery failure: back the task off so
                    # a payload that kills its pilot can't hot-loop through
                    # the fleet at lease-TTL cadence
                    task.not_before = now + self.backoff.delay(task.task_id,
                                                               task.attempts)
                    self._enqueue(task)
            self._update_drained()
            if self._deadlines:                    # re-arm for the next lease
                self._arm_reap_timer(self._deadlines[0][0])
            return len(expired)

    # ---- introspection --------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            self._prune_stale_pilots(time.monotonic())
            return {
                "queued": self._n_queued(),
                "leased": len(self._leases),
                "done": len(self._results),
                "failed": len(self._failed),
                # fresh-heartbeat pilots: the autoscaler's supply-side signal
                "pilots": len(self._pilot_heartbeats),
            }

    def scheduler_metrics(self) -> dict:
        """Match-cost distribution + wakeup accounting for benchmarks."""
        with self._lock:
            lat = sorted(self.match_latencies)
            n = len(lat)
            return {
                "matches": n,
                "match_p50_us": 1e6 * lat[n // 2] if n else 0.0,
                "match_p99_us": 1e6 * lat[min(n - 1, (99 * n) // 100)] if n else 0.0,
                "idle_wakeups": self.idle_wakeups,
                "notifies": self.notifies,
                # timer-callback failures (a crashed lease reaper / monitor
                # tick shows up here instead of silently disabling expiry)
                "timer_errors": self._wheel.error_count,
            }

    def result(self, task_id: int) -> TaskResult | None:
        with self._lock:
            return self._results.get(task_id)

    def failed_tasks(self) -> list[int]:
        """Task ids that settled as failed (attempt budget exhausted) —
        consumers that track work at a higher level (the fleet dispatcher's
        request records) reconcile against this."""
        with self._lock:
            return list(self._failed)

    def drain_done(self) -> bool:
        return self._drained.is_set()

    def wait_drained(self, timeout: float | None = None) -> bool:
        """Block until nothing is queued or leased (event, not a poll)."""
        return self._drained.wait(timeout)
