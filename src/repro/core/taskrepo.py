"""TaskRepo — the overlay task repository (HTCondor schedd analogue).

Pilots fetch payloads by *matchmaking*: a pilot advertises its slice
(devices, mesh shape, memory, labels) and the repo returns the
highest-priority queued task whose requirements match (ClassAd-style
predicates over the pilot ad).  Tasks are *leased*, not popped: a pilot must
heartbeat the lease or it expires and the task is re-queued — the
at-least-once delivery that makes dead pilots harmless (fault tolerance at
1000-node scale).  First completion wins: duplicate results from speculative
re-execution are dropped.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable

Predicate = Callable[[dict], bool]


@dataclasses.dataclass
class PayloadTask:
    task_id: int
    image: Any                          # PayloadImage (core.images)
    requirements: Predicate | None = None
    priority: int = 0
    n_steps: int = 20
    max_wall: float = 120.0             # seconds
    input_files: dict[str, bytes] = dataclasses.field(default_factory=dict)
    env: dict = dataclasses.field(default_factory=dict)
    resume: dict = dataclasses.field(default_factory=dict)  # ckpt info
    attempts: int = 0
    max_attempts: int = 3


@dataclasses.dataclass
class Lease:
    task: PayloadTask
    pilot_id: str
    expires: float


@dataclasses.dataclass
class TaskResult:
    task_id: int
    pilot_id: str
    exitcode: int
    telemetry: dict
    outputs: dict[str, bytes] = dataclasses.field(default_factory=dict)


class TaskRepo:
    def __init__(self, *, lease_ttl: float = 10.0):
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._queue: list[PayloadTask] = []
        self._leases: dict[int, Lease] = {}
        self._results: dict[int, TaskResult] = {}
        self._failed: dict[int, PayloadTask] = {}
        self._pilot_heartbeats: dict[str, float] = {}
        self._step_times: dict[str, float] = {}     # pilot_id -> EWMA
        self.lease_ttl = lease_ttl

    # ---- submission ---------------------------------------------------------

    def submit(self, image, **kw) -> int:
        with self._lock:
            tid = next(self._ids)
            self._queue.append(PayloadTask(task_id=tid, image=image, **kw))
            self._queue.sort(key=lambda t: -t.priority)
            return tid

    # ---- matchmaking (step (b)) ---------------------------------------------

    def match(self, pilot_ad: dict) -> PayloadTask | None:
        """Lease the best matching task for this pilot ad, or None."""
        self.reap_leases()
        with self._lock:
            for i, task in enumerate(self._queue):
                if task.requirements is None or task.requirements(pilot_ad):
                    self._queue.pop(i)
                    task.attempts += 1
                    self._leases[task.task_id] = Lease(
                        task=task, pilot_id=pilot_ad["pilot_id"],
                        expires=time.monotonic() + self.lease_ttl)
                    return task
            return None

    def renew(self, task_id: int, pilot_id: str) -> bool:
        with self._lock:
            lease = self._leases.get(task_id)
            if lease is None or lease.pilot_id != pilot_id:
                return False
            lease.expires = time.monotonic() + self.lease_ttl
            return True

    def heartbeat_pilot(self, pilot_id: str, step_time: float | None = None):
        with self._lock:
            self._pilot_heartbeats[pilot_id] = time.monotonic()
            if step_time is not None:
                prev = self._step_times.get(pilot_id, step_time)
                self._step_times[pilot_id] = 0.7 * prev + 0.3 * step_time

    def fleet_median_step_time(self) -> float | None:
        with self._lock:
            vals = sorted(self._step_times.values())
        if not vals:
            return None
        return vals[len(vals) // 2]

    # ---- completion (step (e)): first-wins ----------------------------------

    def complete(self, result: TaskResult) -> bool:
        """Returns True if this result was accepted (first completion wins;
        speculative duplicates are dropped).  Non-zero exits are NOT stored —
        the pilot follows up with release(task, failed=True) to retry/fail."""
        with self._lock:
            self._leases.pop(result.task_id, None)
            if result.task_id in self._results:
                return False                       # speculative duplicate
            if result.exitcode == 0:
                self._results[result.task_id] = result
                return True
            return False

    def release(self, task: PayloadTask, *, failed: bool = False):
        """Give a leased task back (pilot draining, or payload failure)."""
        with self._lock:
            self._leases.pop(task.task_id, None)
            if task.task_id in self._results:
                return
            if failed and task.attempts >= task.max_attempts:
                self._failed[task.task_id] = task
                return
            self._queue.append(task)
            self._queue.sort(key=lambda t: -t.priority)

    # ---- lease reaping (dead pilots) -----------------------------------------

    def reap_leases(self) -> int:
        now = time.monotonic()
        with self._lock:
            expired = [l for l in self._leases.values() if l.expires < now]
            for l in expired:
                del self._leases[l.task.task_id]
        for l in expired:
            self.release(l.task, failed=False)
        return len(expired)

    # ---- introspection --------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {
                "queued": len(self._queue),
                "leased": len(self._leases),
                "done": len(self._results),
                "failed": len(self._failed),
            }

    def result(self, task_id: int) -> TaskResult | None:
        with self._lock:
            return self._results.get(task_id)

    def drain_done(self) -> bool:
        s = self.stats()
        return s["queued"] == 0 and s["leased"] == 0
