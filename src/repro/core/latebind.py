"""PayloadExecutor — the payload container + the late-binding image patch.

The executor is the pod's second container (paper §3.3):

* At pod creation it holds the PLACEHOLDER image and its run thread blocks in
  the arena's wait-for-startup-spec loop — Kubernetes is satisfied (every
  container has an image) while no payload exists yet.
* ``patch_image()`` is the unprivileged ``kubectl set image`` / pod-patch:
  it requires a capability token scoped to *this pod only* (the "pod patch
  role inside its own namespace"), swaps the executable in place, and never
  touches the resource grant — the slice stays claimed throughout.
* ``reset()`` is the §3.6 cleanup-by-container-restart: the payload's
  process entries are killed and its device state dropped; the pilot's state
  survives untouched.

Compilation happens at patch time via the ExecutableRegistry (the image
pull); a warm cache makes rebinding nearly free — the measurable win of
late-binding over re-provisioning.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

from repro.analysis.locks import audit_callback, make_condition, make_lock
from repro.core.arena import SharedArena
from repro.core.images import Executable, ExecutableRegistry, PLACEHOLDER, PayloadImage
from repro.core.proctable import PAYLOAD_UID, ProcessTable
from repro.core.wrapper import run_wrapper

UNBOUND = "unbound"
BOUND = "bound"
RUNNING = "running"
EXITED = "exited"


class PermissionError_(Exception):
    """Capability check failed (wrong pod / not the pilot)."""


@dataclasses.dataclass(frozen=True)
class PodPatchCapability:
    """The pilot's credential (§3.3): may patch images of its own pod only."""
    pod_id: str


class PayloadExecutor:
    def __init__(self, pod_id: str, arena: SharedArena,
                 proctable: ProcessTable, registry: ExecutableRegistry,
                 mesh=None):
        self.pod_id = pod_id
        self.arena = arena
        self.proctable = proctable
        self.registry = registry
        self.mesh = mesh
        self.image: PayloadImage = PLACEHOLDER
        self.exe: Executable | None = registry.pull(PLACEHOLDER, mesh)
        self.state = UNBOUND
        self.generation = 0               # bumped by every restart/patch
        self.exit_event: threading.Event | None = None
        self._lock = make_lock("latebind.executor")
        # the persistent container-runtime thread: entrypoint generations
        # boot from a queue instead of spawning a thread per payload
        self._boot_cond = make_condition(name="latebind.boot")
        self._boot: tuple | None = None
        self._runtime: threading.Thread | None = None
        self._closed = False
        self.last_bind_seconds: float | None = None
        self.last_bind_cached: bool | None = None

    # ------------------------------------------------------------------
    # the unprivileged pod patch
    # ------------------------------------------------------------------

    def patch_image(self, cap: PodPatchCapability, image: PayloadImage):
        if cap.pod_id != self.pod_id:
            raise PermissionError_(
                f"capability for pod {cap.pod_id!r} cannot patch {self.pod_id!r}")
        t0 = time.monotonic()
        exe = self.registry.pull(image, self.mesh)      # the image pull
        with self._lock:
            self.image = image
            self.exe = exe
            self.state = BOUND
            self.generation += 1
        self.last_bind_seconds = time.monotonic() - t0
        self.last_bind_cached = exe.cached
        return exe

    # ------------------------------------------------------------------
    # container start: wait-for-spec loop, then run the wrapper
    # ------------------------------------------------------------------

    def start(self, *, spec_timeout: float = 30.0, on_exit=None):
        """Start the payload container's entrypoint (async).

        ``on_exit`` (optional) is called exactly once when the container's
        entrypoint finishes, on the container thread — the pilot's
        event-driven collection hook.  ``exit_event`` is set at the same
        point, so observers can block without polling ``running``.
        """
        if self.running:
            raise RuntimeError("payload container already running")
        done = threading.Event()
        self.exit_event = done
        with self._boot_cond:
            self._boot = (self.generation, spec_timeout, on_exit, done)
            if self._runtime is None or not self._runtime.is_alive():
                self._runtime = threading.Thread(
                    target=self._runtime_loop, daemon=True,
                    name=f"payload-container-{self.pod_id}")
                self._runtime.start()
            self._boot_cond.notify()

    def _runtime_loop(self):
        """One thread per pod for the container runtime: it parks between
        payloads and boots each entrypoint generation from the queue."""
        while True:
            with self._boot_cond:
                while self._boot is None and not self._closed:
                    self._boot_cond.wait()
                if self._boot is None:    # closed with nothing queued
                    return
                gen, spec_timeout, on_exit, done = self._boot
                self._boot = None
            try:
                spec = self.arena.wait_for_startup_spec(timeout=spec_timeout)
                with self._lock:
                    stale = self.generation != gen    # restarted while waiting
                    exe = self.exe
                if stale:
                    continue
                if spec is None:
                    self.arena.report_exit(124, {"error": "startup spec timeout"})
                    self.state = EXITED
                else:
                    self.state = RUNNING
                    run_wrapper(self.arena, self.proctable, exe, spec)
                    self.state = EXITED
            except Exception:             # noqa: BLE001 — runtime survives
                self.state = EXITED
            finally:
                done.set()
                if on_exit is not None:
                    try:
                        audit_callback("latebind:on_exit")
                        on_exit()
                    except Exception:     # noqa: BLE001
                        pass

    def close(self):
        """Tear down the pod: stop the container-runtime thread once the
        current entrypoint (if any) finishes.  Terminated pilots must call
        this or every pilot ever created leaks a parked thread."""
        with self._boot_cond:
            self._closed = True
            self._boot_cond.notify()

    def join(self, timeout: float | None = None) -> bool:
        """Wait for the current entrypoint generation to finish."""
        ev = self.exit_event
        if ev is None:
            return True
        return ev.wait(timeout)

    def wait_exit(self, timeout: float | None = None) -> bool:
        """Block on the completion event (microsecond wake-up, no polling)."""
        return self.join(timeout)

    @property
    def running(self) -> bool:
        ev = self.exit_event
        return ev is not None and not ev.is_set()

    # ------------------------------------------------------------------
    # cleanup by restart (§3.6)
    # ------------------------------------------------------------------

    def reset(self, *, back_to_placeholder: bool = False):
        """Kubernetes-runtime cleanup: kill the payload process tree, drop
        payload device state, bump the generation."""
        self.proctable.kill_uid(PAYLOAD_UID)
        self.join(timeout=5.0)
        with self._lock:
            self.generation += 1
            self.exit_event = None
            if back_to_placeholder:
                self.image = PLACEHOLDER
                self.exe = self.registry.pull(PLACEHOLDER, self.mesh)
                self.state = UNBOUND
            else:
                self.state = BOUND if self.exe is not None else UNBOUND
        self.proctable.reap()
