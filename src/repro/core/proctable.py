"""ProcessTable — the shared process namespace + uid model (paper §3.4).

In the paper, the pilot sees the payload's processes because the pod shares
one process namespace, and tells them apart by a reserved payload UID; the
pilot keeps the pseudo-root UID so it can signal/kill payload processes while
the payload cannot touch the pilot's.

Here every host-side activity (pilot threads, payload step loops) registers
an entry tagged with a uid.  The pilot (uid 0) may enumerate and signal any
entry; a payload capability can only see/affect entries of its own uid —
enforced by the capability object, the analogue of the kernel refusing
signals across UIDs.  Termination is cooperative at step boundaries (the
same place HTCondor applies policy), via a stop Event the running loop
checks between steps.
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro.analysis.locks import audit_callback, make_lock

PILOT_UID = 0
PAYLOAD_UID = 1000        # the paper's well-defined, pre-determined UID


@dataclasses.dataclass
class ProcEntry:
    pid: int
    uid: int
    name: str
    started: float
    stop: threading.Event
    # graceful wind-down request (SIGTERM-with-grace analogue): a payload
    # that honors it stops taking NEW work, hands leased work back, and
    # exits cleanly — unlike `stop`, which is the hard kill
    drain: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    state: str = "running"            # running | exited | killed
    exitcode: int | None = None
    last_step_time: float | None = None
    steps_done: int = 0

    def request_stop(self):
        self.stop.set()


class ProcessTable:
    """Event-driven: observers subscribe to ``exit`` and ``step`` events
    instead of scanning the table on a timer.  Callbacks fire on the thread
    that caused the event, outside the table lock (no lock-order hazards);
    they must be short and exception-safe."""

    def __init__(self):
        self._lock = make_lock("proctable.table")
        self._next_pid = 1
        self._entries: dict[int, ProcEntry] = {}
        self._listeners: list = []        # callables (kind, entry)
        self._drained_uids: set[int] = set()   # sticky drain (see drain_uid)

    def subscribe(self, fn) -> None:
        """fn(kind, entry) with kind in {"exit", "step"}."""
        with self._lock:
            self._listeners.append(fn)

    def unsubscribe(self, fn) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _notify(self, kind: str, entry: ProcEntry):
        with self._lock:
            listeners = list(self._listeners)
        audit_callback(f"proctable:{kind}")
        for fn in listeners:
            try:
                fn(kind, entry)
            except Exception:             # noqa: BLE001
                pass

    def register(self, uid: int, name: str) -> ProcEntry:
        with self._lock:
            pid = self._next_pid
            self._next_pid += 1
            e = ProcEntry(pid=pid, uid=uid, name=name, started=time.monotonic(),
                          stop=threading.Event())
            if uid in self._drained_uids:    # the uid is winding down: a
                e.drain.set()                # late-registering process starts
            self._entries[pid] = e           # pre-drained (no race window)
            return e

    def mark_exited(self, pid: int, exitcode: int):
        with self._lock:
            e = self._entries.get(pid)
            if e and e.state == "running":
                e.state = "exited"
                e.exitcode = exitcode
            else:
                e = None
        if e is not None:
            self._notify("exit", e)

    def heartbeat(self, pid: int, step_time: float):
        with self._lock:
            e = self._entries.get(pid)
            if e:
                e.last_step_time = step_time
                e.steps_done += 1
        if e is not None:
            self._notify("step", e)

    # ---- enumeration: uid-scoped, like `ps` in a shared namespace ----------

    def entries(self, *, uid: int | None = None, viewer_uid: int = PILOT_UID
                ) -> list[ProcEntry]:
        with self._lock:
            out = list(self._entries.values())
        if viewer_uid != PILOT_UID:
            out = [e for e in out if e.uid == viewer_uid]
        if uid is not None:
            out = [e for e in out if e.uid == uid]
        return out

    # ---- signalling ---------------------------------------------------------

    def kill(self, pid: int, *, signaller_uid: int = PILOT_UID) -> bool:
        """Cooperative SIGTERM.  Non-pilot uids may only signal their own."""
        with self._lock:
            e = self._entries.get(pid)
            if e is None:
                return False
            if signaller_uid != PILOT_UID and e.uid != signaller_uid:
                return False           # EPERM — the uid protection of §3.4
            e.stop.set()
            if e.state == "running":
                e.state = "killed"
            return True

    def drain_uid(self, uid: int, *, signaller_uid: int = PILOT_UID) -> int:
        """Graceful wind-down for every process of a uid (the pilot's
        scale-down path): sets each entry's ``drain`` event and remembers
        the uid, so a payload that registers AFTER the drain request (the
        pilot was draining while its container booted) still starts
        drained.  Unlike :meth:`kill_uid`, nothing is marked killed — the
        payload exits on its own, releasing leased work first."""
        if signaller_uid != PILOT_UID:
            return 0                       # EPERM — pilot-only control
        with self._lock:
            self._drained_uids.add(uid)
            entries = [e for e in self._entries.values() if e.uid == uid]
        for e in entries:
            e.drain.set()
        return len(entries)

    def kill_uid(self, uid: int, *, signaller_uid: int = PILOT_UID) -> int:
        """Kill every process of a uid (the pilot's orphan sweep, step (f))."""
        n = 0
        for e in self.entries(uid=uid):
            if self.kill(e.pid, signaller_uid=signaller_uid):
                n += 1
        return n

    def reap(self):
        with self._lock:
            dead = [p for p, e in self._entries.items() if e.state != "running"]
            for p in dead:
                del self._entries[p]
            return len(dead)
