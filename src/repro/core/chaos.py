"""Chaos fault injection for the pilot fleet (gray-failure drills).

The paper's pilot model targets opportunistic, preemptible Kubernetes
slices where disruption is NORMAL operation — and production dHTC
failures are mostly *gray*, not clean crashes: payloads that stall while
still renewing their leases, pilots running 5-10x slow, heartbeats that
silently drop, network partitions that cut the control plane while the
payload keeps computing, and poison requests that serially kill every
pilot they touch.  This module injects exactly those faults into a
running :class:`~repro.core.cluster.ClusterSim` fleet on a declarative
schedule, so the hardening layers (progress watchdog, hedged
re-dispatch, backoff requeue, poison quarantine — see
``serving/dispatch.py``) can be driven end to end by a scripted trace.

Fault taxonomy (``FaultSpec.kind``):

``crash``
    Hard node loss via ``ClusterSim.fail_pilot`` — the one fault the
    substrate already survives (PR 4).  Included so chaos plans can mix
    clean and gray failures.
``stall``
    The serve payload stops making progress but KEEPS renewing its
    leases — invisible to the lease-expiry reaper by construction; only
    the dispatcher's progress watchdog can see it.
``slow``
    Step-time inflation by ``factor`` — the straggler that hedged
    re-dispatch rescues.
``flaky_heartbeat``
    Telemetry samples (``report_telemetry``) drop with probability
    ``drop_rate`` (deterministic per-site RNG) — the autoscaler's
    demand signal degrades but leases stay healthy.
``partition``
    Control-plane cut: lease renewals, fetches, and completions all
    fail while the payload keeps computing.  Leases expire and the work
    is replayed elsewhere; if the partition heals first, the original
    may still race the replay (first completion wins keeps it exactly
    once either way).

Injection is *cooperative and unprivileged*, matching the repo's
simulation idiom: the serve loop (``core/wrapper.py``) and the pilot's
renew tick (``core/pilot.py``) consult :func:`site` — a process-global
per-server fault register — at each tick.  When no controller is
installed the lookup is one dict probe returning ``None``, so the hot
path costs nothing outside chaos drills.

Poison requests: a request entry carrying ``{"poison": True}`` is only
*lethal* while a controller with ``FaultPlan.poison`` is installed — the
serve loop calls :meth:`ChaosSite.trip_poison` when it fetches one,
which hard-kills the pilot (the request's lease then expires and the
dispatcher's blast-radius accounting takes over).
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
import zlib

from repro.analysis.locks import make_lock


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.  ``at_s`` is the offset from
    :meth:`ChaosController.start`; gray faults last ``duration_s`` and
    clear themselves (stamp-based — no end events to miss)."""
    kind: str                       # crash|stall|slow|flaky_heartbeat|partition
    at_s: float = 0.0
    duration_s: float = 1.0
    factor: float = 4.0             # slow: step-time inflation multiple
    drop_rate: float = 0.75        # flaky_heartbeat: P(sample dropped)
    victim: str | None = None       # explicit pilot_id; None = pick
    pick: str = "most-leases"       # most-leases | random


@dataclasses.dataclass
class FaultPlan:
    """A declarative chaos trace: scheduled faults + whether poison
    request entries are armed (lethal) for the run."""
    faults: list[FaultSpec] = dataclasses.field(default_factory=list)
    poison: bool = False
    seed: int = 0


class ChaosSite:
    """Per-server gray-fault state, consulted from inside the payload.

    All fields are plain floats/bools written by the controller thread
    and read by the serve loop — single-word updates under the GIL, no
    lock on the per-tick read path."""

    def __init__(self, server_id: str, controller: "ChaosController"):
        self.server_id = server_id
        self._controller = controller
        self._rng = random.Random(controller.seed
                                  ^ zlib.crc32(server_id.encode()))
        self.stall_until = 0.0
        self.slow_until = 0.0
        self.slow_by = 1.0
        self.cut_until = 0.0
        self.flaky_until = 0.0
        self.drop_rate = 0.0

    # -- per-tick queries (hot path: no locks) --------------------------

    def stalled(self) -> bool:
        return time.monotonic() < self.stall_until

    def slow_factor(self) -> float:
        return self.slow_by if time.monotonic() < self.slow_until else 1.0

    def partitioned(self) -> bool:
        return time.monotonic() < self.cut_until

    def drop_heartbeat(self) -> bool:
        if time.monotonic() >= self.flaky_until:
            return False
        return self._rng.random() < self.drop_rate

    def poison_lethal(self) -> bool:
        return self._controller.poison_armed

    def trip_poison(self, rid: int):
        """The server fetched a poison request: detonate (kill this
        pilot).  Called from the serve loop, which returns 143 right
        after — the lease is never released and expires normally."""
        self._controller._trip_poison(self.server_id, rid)


# -- process-global site registry (the simulation's "is chaos on?") -----

_LOCK = make_lock("chaos.install")
_ACTIVE: "ChaosController | None" = None


def site(server_id: str) -> ChaosSite | None:
    """The fault register for ``server_id``, or None when no chaos
    controller is installed (the common case — one attribute read)."""
    c = _ACTIVE
    return c.site_for(server_id) if c is not None else None


class ChaosController:
    """Executes a :class:`FaultPlan` against a live fleet.

    Usage::

        ctl = ChaosController(sim, fleet, pool=pool, plan=plan)
        ctl.start()          # t=0 for every FaultSpec.at_s
        ...traffic...
        ctl.stop()           # uninstalls; pending faults are dropped

    Only one controller is installed at a time (process-global, like the
    dispatcher pool registry).  ``log`` records every fault actually
    applied — benchmarks introspect it for gates like "poison killed at
    most 2 pilots"."""

    def __init__(self, sim, fleet=None, *, pool=None,
                 plan: FaultPlan | None = None):
        self.sim = sim
        self.fleet = fleet
        self.pool = pool
        self.plan = plan or FaultPlan()
        self.seed = self.plan.seed
        self.poison_armed = bool(self.plan.poison)
        self._rng = random.Random(self.seed)
        self._sites: dict[str, ChaosSite] = {}
        self._sites_lock = make_lock("chaos.sites")
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.log: list[dict] = []
        self.poison_kills: dict[int, int] = {}   # rid -> pilots killed
        self._victims: set[str] = set()          # pilots already targeted

    # -- site registry ---------------------------------------------------

    def site_for(self, server_id: str) -> ChaosSite:
        with self._sites_lock:
            s = self._sites.get(server_id)
            if s is None:
                s = self._sites[server_id] = ChaosSite(server_id, self)
            return s

    # -- lifecycle -------------------------------------------------------

    def start(self):
        global _ACTIVE
        with _LOCK:
            if _ACTIVE is not None and _ACTIVE is not self:
                raise RuntimeError("another ChaosController is installed")
            _ACTIVE = self
        self._stop.clear()
        self.t0 = time.monotonic()
        if self.plan.faults:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="chaos-controller")
            self._thread.start()
        return self

    def stop(self):
        global _ACTIVE
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        with _LOCK:
            if _ACTIVE is self:
                _ACTIVE = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- the schedule ----------------------------------------------------

    def _run(self):
        for f in sorted(self.plan.faults, key=lambda f: f.at_s):
            delay = self.t0 + f.at_s - time.monotonic()
            if delay > 0 and self._stop.wait(delay):
                return
            if self._stop.is_set():
                return
            try:
                self._apply(f)
            except Exception as e:       # noqa: BLE001 — a fault that fails
                # to land must not kill the remaining schedule
                self.log.append({"t": time.monotonic() - self.t0,
                                 "kind": f.kind, "error": repr(e)})

    def _apply(self, f: FaultSpec):
        victim = f.victim or self._pick(f)
        if victim is None:
            self.log.append({"t": time.monotonic() - self.t0,
                             "kind": f.kind, "victim": None,
                             "error": "no candidate"})
            return
        self._victims.add(victim)
        now = time.monotonic()
        if f.kind == "crash":
            self.kill_pilot(victim)
        else:
            s = self.site_for(victim)
            if f.kind == "stall":
                s.stall_until = now + f.duration_s
            elif f.kind == "slow":
                s.slow_by = f.factor
                s.slow_until = now + f.duration_s
            elif f.kind == "flaky_heartbeat":
                s.drop_rate = f.drop_rate
                s.flaky_until = now + f.duration_s
            elif f.kind == "partition":
                s.cut_until = now + f.duration_s
            else:
                raise ValueError(f"unknown fault kind {f.kind!r}")
        self.log.append({"t": now - self.t0, "kind": f.kind,
                         "victim": victim})

    def _pick(self, f: FaultSpec) -> str | None:
        """Victim selection among LIVE pilots not yet targeted (a plan's
        faults spread across the fleet; re-targeting a crashed pilot
        exercises nothing).  Falls back to already-targeted live pilots
        when every pilot has been hit."""
        live = ([p.pilot_id for p in self.fleet.live()]
                if self.fleet is not None
                else [p.pilot_id for p in self.sim.live_pilots()])
        if not live:
            return None
        fresh = [p for p in live if p not in self._victims] or live
        if f.pick == "most-leases" and self.pool is not None:
            holders = self.pool.lease_holders()
            fresh.sort(key=lambda p: -len(holders.get(p, [])))
            return fresh[0]
        return fresh[self._rng.randrange(len(fresh))]

    # -- actuators -------------------------------------------------------

    def kill_pilot(self, pilot_id: str) -> bool:
        return self.sim.fail_pilot(pilot_id)

    def _trip_poison(self, server_id: str, rid: int):
        self.poison_kills[rid] = self.poison_kills.get(rid, 0) + 1
        self.log.append({"t": time.monotonic() - self.t0, "kind": "poison",
                         "victim": server_id, "rid": rid})
        self.kill_pilot(server_id)

    def stats(self) -> dict:
        return {
            "faults_applied": len([e for e in self.log
                                   if "error" not in e]),
            "poison_kills": dict(self.poison_kills),
            "log": list(self.log),
        }
