"""PayloadImage + ExecutableRegistry — container images and the image cache.

A *PayloadImage* names everything needed to build the payload's executable:
(architecture x input shape x step kind x flags).  "Pulling" an image is XLA
compilation against the slice's mesh; the registry's cache plays the node's
local image cache — a warm ``bind()`` skips compilation exactly as a cached
image skips the pull (measured in benchmarks/bind_latency.py).

The PLACEHOLDER image is the paper's arbitrary default container image: a
trivial executable every slice can always run, installed at pod creation so
the Kubernetes-side object is valid before any payload exists (§3.3).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.analysis.locks import make_lock
from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, get_config, get_smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.api import build_model
from repro.optim.adamw import OptimConfig
from repro.runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class PayloadImage:
    """Immutable image reference (the `image:` field of the pod spec)."""
    arch: str                        # registry name, or "<name>-smoke"
    shape: str                       # key into SHAPES, or "smoke"
    mode: str                        # "train" | "prefill" | "decode" | "serve" | "noop"
    smoke: bool = True               # reduced config (tests/examples) vs full
    flags: tuple = ()                # e.g. (("remat","dots"), ("attn_impl","causal_blocked"))
    # serve mode only: registry name of a DRAFT model for speculative
    # decoding.  Like the arch itself, the draft choice is a late-binding
    # decision — it names a different image (own compile-cache key), and
    # engines from the image default to spec="draft" with this draft.
    draft: str | None = None
    # serve mode only: SPMD device-mesh shape ``(data, model)`` the image's
    # engines run on (None = single device).  Mesh shape is a LATE-BINDING
    # decision exactly like the arch: a pilot claims devices first, and the
    # mesh-shaped executable binds after — so it is part of ``key()`` and
    # the registry compiles/warms once per (image, mesh).
    mesh_shape: tuple | None = None
    # serve mode only: the engine's serving ROLE in a disaggregated fleet
    # ("prefill" | "decode" | "unified").  Role is a late-binding decision
    # exactly like the arch — a pilot claims a slice first and the role
    # shapes which step fns the image compiles — so it is part of ``key()``
    # and a prefill-only image never pays the decode-step compile.
    role: str = "unified"

    def key(self) -> tuple:
        return (self.arch, self.shape, self.mode, self.smoke, self.flags,
                self.draft, self.mesh_shape, self.role)

    def build_mesh(self):
        """The serve mesh this image requests, or None (single device)."""
        if self.mesh_shape is None:
            return None
        from repro.runtime.mesh import serve_mesh
        return serve_mesh(self.mesh_shape)

    def config(self) -> ArchConfig:
        cfg = get_smoke_config(self.arch) if self.smoke else get_config(self.arch)
        if self.flags:
            cfg = dataclasses.replace(cfg, **dict(self.flags))
        return cfg

    def shape_spec(self) -> ShapeSpec:
        if self.shape in SHAPES:
            return SHAPES[self.shape]
        if self.shape.startswith("custom:"):        # "custom:<seq>x<batch>"
            seq, batch = self.shape.split(":", 1)[1].split("x")
            return ShapeSpec(self.shape, int(seq), int(batch), self.mode)
        # smoke shapes: tiny, CPU-runnable
        mode = "train" if self.mode == "train" else self.mode
        return ShapeSpec("smoke", 64, 2, mode)


PLACEHOLDER = PayloadImage(arch="placeholder", shape="none", mode="noop")


@dataclasses.dataclass
class Executable:
    """A pulled image: compiled function + input builders."""
    image: PayloadImage
    fn: Any                           # jitted/compiled callable
    make_inputs: Any                  # (key) -> concrete input pytree
    compile_seconds: float
    cached: bool = False
    # force the lazy XLA compile now (one representative invocation);
    # None for modes whose compile cannot be staged ahead (serve engines
    # jit per instance).  prefetch() runs this in the background so the
    # whole pull — python build AND XLA compile — overlaps the current
    # payload instead of landing on the next bind's first step.
    warm: Any = None


class ExecutableRegistry:
    """Compile cache keyed by (image, mesh shape).  Thread-safe; one compile
    per key even under concurrent binds (single-flight)."""

    def __init__(self):
        self._lock = make_lock("images.registry")
        self._cache: dict[tuple, Executable] = {}
        self._inflight: dict[tuple, threading.Event] = {}
        self._prefetching: dict[tuple, threading.Event] = {}
        self.stats = {"hits": 0, "misses": 0, "prefetches": 0}

    @staticmethod
    def _key(image: PayloadImage, mesh) -> tuple:
        return (image.key(), None if mesh is None else
                (tuple(mesh.devices.shape), tuple(mesh.axis_names)))

    def prefetch(self, image: PayloadImage, mesh=None) -> threading.Event:
        """Start pulling an image in the BACKGROUND and return an event that
        is set once it is cached.  Single-flight with `pull`: a concurrent
        bind for the same key waits on the same compile instead of starting
        a second one, and a later `pull` that lands mid-compile parks on the
        inflight event and then takes the cache hit.

        This is how a pilot overlaps the next task's image pull with the
        current payload's run (the hint rides on the matched task) — the
        late-binding analogue of a kubelet pre-pulling the next image while
        the current container still executes.
        """
        key = self._key(image, mesh)
        with self._lock:
            ev = self._prefetching.get(key)
            if ev is not None:                # join the in-progress prefetch:
                return ev                     # set only after warm() finishes
            done = threading.Event()
            if key in self._cache:
                done.set()
                return done
            # claim the key under the lock so concurrent prefetches of the
            # same image join `done` instead of spawning a second worker
            self._prefetching[key] = done
            self.stats["prefetches"] += 1

        def work():
            try:
                # pull() joins any concurrent bind's compile (single-flight)
                exe = self.pull(image, mesh)
                if exe.warm is not None:
                    exe.warm()            # stage the lazy XLA compile too
            except Exception:             # noqa: BLE001 — prefetch is a hint
                pass
            finally:
                with self._lock:
                    self._prefetching.pop(key, None)
                done.set()

        threading.Thread(target=work, daemon=True,
                         name=f"prefetch-{image.arch}:{image.mode}").start()
        return done

    def pull(self, image: PayloadImage, mesh=None) -> Executable:
        key = self._key(image, mesh)
        while True:
            with self._lock:
                if key in self._cache:
                    self.stats["hits"] += 1
                    e = self._cache[key]
                    return Executable(e.image, e.fn, e.make_inputs,
                                      e.compile_seconds, cached=True,
                                      warm=e.warm)
                ev = self._inflight.get(key)
                if ev is None:
                    self._inflight[key] = threading.Event()
                    break
            ev.wait()                    # another bind is compiling this image
        try:
            exe = self._build(image, mesh)
            with self._lock:
                self._cache[key] = exe
                self.stats["misses"] += 1
            return exe
        finally:
            with self._lock:
                ev = self._inflight.pop(key)
            ev.set()

    # ------------------------------------------------------------------

    def _build(self, image: PayloadImage, mesh) -> Executable:
        t0 = time.monotonic()
        if image.mode == "noop":
            fn = jax.jit(lambda x: x + 1.0)
            fn(jnp.zeros(()))            # warm
            return Executable(image, fn, lambda key: jnp.zeros(()),
                              time.monotonic() - t0)

        cfg = image.config()
        shape = image.shape_spec()
        bundle = build_model(cfg)

        warm = None
        if image.mode == "train":
            step = make_train_step(cfg, OptimConfig(total_steps=1000))
            fn = jax.jit(step, donate_argnums=0)

            def make_inputs(key):
                from repro.launch.steps import init_train_state
                from repro.data.synthetic import SyntheticConfig, SyntheticLM
                state = init_train_state(cfg, key)
                data = SyntheticLM(SyntheticConfig(
                    cfg.vocab_size, _text_len(cfg, shape.seq_len),
                    shape.global_batch))
                return state, data

            def warm():
                state, data = make_inputs(jax.random.key(0))
                batch = {k: jnp.asarray(v)
                         for k, v in data.batch_at(0).items()}
                jax.block_until_ready(fn(state, batch)[1]["loss"])
        elif image.mode == "prefill":
            step = make_prefill_step(cfg)
            fn = jax.jit(step)

            def make_inputs(key):
                params = bundle.init(key)
                batch = _concrete_batch(cfg, shape, key, with_targets=False)
                return params, batch

            def warm():
                jax.block_until_ready(fn(*make_inputs(jax.random.key(0)))[0])
        elif image.mode == "serve":
            # a serve image is an ENGINE factory: the wrapper builds a
            # continuous-batching ServeEngine over freshly-initialized params
            # and drives it either from the request trace in the startup
            # spec or — when the spec names a fleet pool ("dispatch") — by
            # leasing requests out of a FleetDispatcher, where a dead
            # server's in-flight requests requeue onto survivors.
            # Every engine from this factory shares ONE jitted step (per
            # max_len), ONE jitted prefill wrapper and ONE chunked-prefill
            # wrapper, so warm() can stage the XLA compiles at prefetch time
            # and the payload's first tick hits the cache; params come from
            # the image's seed, so every server in a fleet serves IDENTICAL
            # weights — what makes replay-from-prompt reproduce a dead
            # server's tokens bitwise.
            from repro.serving.engine import (
                ServeEngine, _traced_under_mesh, make_draft_step,
                make_engine_step, make_verify_step,
            )

            # the image's requested serve mesh (late binding: the slice's
            # devices are already held; this shapes the executable over
            # them).  One mesh per factory — a different mesh_shape is a
            # different image key, so the registry keeps the compiles apart.
            eng_mesh = image.build_mesh()
            step_fns: dict[int, Any] = {}
            prefill_fn = jax.jit(_traced_under_mesh(bundle.prefill,
                                                    eng_mesh))
            chunk_fn = (jax.jit(_traced_under_mesh(bundle.prefill_chunk,
                                                   eng_mesh),
                        donate_argnums=1)
                        if bundle.prefill_chunk is not None else None)
            # the draft model is part of the image: one bundle, one fixed-
            # seed param set and one jitted prefill shared by every engine
            # the factory builds — so a fleet's servers draft (and replay)
            # bitwise-identically, and a registry prefetch stages the draft
            # compiles alongside the target's
            draft_cfg = draft_bundle = draft_prefill_fn = None
            draft_params_cache: dict[str, Any] = {}
            if image.draft:
                draft_cfg = (get_smoke_config(image.draft) if image.smoke
                             else get_config(image.draft))
                draft_bundle = build_model(draft_cfg)
                draft_prefill_fn = jax.jit(
                    _traced_under_mesh(draft_bundle.prefill, eng_mesh))
            spec_fns: dict[tuple, Any] = {}

            def step_for(max_len):
                if max_len not in step_fns:
                    step_fns[max_len] = make_engine_step(bundle, max_len,
                                                         mesh=eng_mesh)
                return step_fns[max_len]

            def spec_for(max_len, k):
                if (max_len, k) not in spec_fns:
                    spec_fns[(max_len, k)] = (
                        make_draft_step(draft_bundle or bundle, k, max_len,
                                        mesh=eng_mesh),
                        make_verify_step(bundle, max_len, k, mesh=eng_mesh))
                return spec_fns[(max_len, k)]

            def draft_params_for():
                if "params" not in draft_params_cache:
                    draft_params_cache["params"] = draft_bundle.init(
                        jax.random.key(0))
                return draft_params_cache["params"]

            def fn(params, slots=None, max_len=None, mesh_shape=None, **kw):
                ml = max_len or shape.seq_len
                mesh = eng_mesh
                shared = True
                kw.setdefault("role", image.role)
                role = kw["role"]
                if mesh_shape is not None \
                        and tuple(mesh_shape) != image.mesh_shape:
                    # startup-spec override of the image's mesh: correct
                    # but unprefetched — the engine jits its own steps for
                    # the off-image geometry (first tick pays the compile)
                    from repro.runtime.mesh import serve_mesh
                    mesh = serve_mesh(tuple(mesh_shape))
                    shared = False
                if image.draft and role == "unified":
                    # non-unified roles force spec off (draft KV does not
                    # ride the handoff) — don't stage draft fns they drop
                    kw.setdefault("spec", "draft")
                if kw.get("spec") == "draft":
                    kw.setdefault("spec_k", 4)
                    if shared:
                        dfn, vfn = spec_for(ml, int(kw["spec_k"]))
                        kw.setdefault("draft_fn", dfn)
                        kw.setdefault("verify_fn", vfn)
                    if draft_bundle is not None:
                        kw.setdefault("draft_cfg", draft_cfg)
                        kw.setdefault("draft_bundle", draft_bundle)
                        kw.setdefault("draft_params", draft_params_for())
                        if shared:
                            kw.setdefault("draft_prefill_fn",
                                          draft_prefill_fn)
                return ServeEngine(cfg, params,
                                   slots=slots or shape.global_batch,
                                   max_len=ml, bundle=bundle,
                                   step_fn=(step_for(ml)
                                            if shared and role != "prefill"
                                            else None),
                                   prefill_fn=prefill_fn if shared else None,
                                   chunk_fn=chunk_fn if shared else None,
                                   mesh=mesh, **kw)

            def make_inputs(key):
                return bundle.init(key)

            def warm():
                # build a throwaway engine THROUGH the factory so the
                # staged shapes (KV layout, pool size, buckets, chunk
                # shapes) are exactly what served engines will use — the
                # jit wrappers are shared, so every compile lands in the
                # caches production engines hit.  Specs that override
                # engine geometry (num_blocks/block_size/prefill_chunk)
                # trade this prewarm for a first-tick compile.
                params = bundle.init(jax.random.key(0))
                eng = fn(params, prefill="chunked")
                eng.warm_admission()   # buckets + chunk shapes (+ draft);
                #                        no-op for a decode-role engine
                if eng.role == "prefill":
                    return             # exports at admission — the decode
                #                        step never runs on this image
                if eng.spec == "draft":
                    # stage the draft-chain and k-position verify compiles
                    # (the decode loop a speculative engine actually runs)
                    drafts, eng._draft_cache = eng._draft_fn(
                        eng.draft_params, eng._draft_cache,
                        eng.state["token"], eng.state["pos"],
                        eng.state["block_tables"])
                    out = eng._verify_fn(params, eng.state, eng.active,
                                         eng.budget, drafts)
                else:
                    out = eng._step_fn(params, eng.state, eng.active,
                                       eng.budget)  # the decode-step compile
                jax.block_until_ready(out[0])
        else:                            # decode
            step = make_serve_step(cfg)
            fn = jax.jit(step, donate_argnums=1)

            def make_inputs(key):
                from repro.models.api import init_decode_state
                params = bundle.init(key)
                state = init_decode_state(cfg, shape.global_batch,
                                          shape.seq_len)
                return params, state

            def warm():
                jax.block_until_ready(fn(*make_inputs(jax.random.key(0)))[0])

        return Executable(image, fn, make_inputs, time.monotonic() - t0,
                          warm=warm)


def _text_len(cfg, seq_len):
    return seq_len - cfg.frontend_tokens if cfg.family == "vlm" else seq_len


def _concrete_batch(cfg, shape, key, *, with_targets=True):
    B = shape.global_batch
    S = _text_len(cfg, shape.seq_len)
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if with_targets:
        batch["targets"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = jax.random.normal(
            ks[2], (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16) * 0.02
    return batch
