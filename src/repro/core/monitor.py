"""Pilot-side monitor (paper §3.4 + dHTC straggler mitigation).

The monitor periodically scans the shared process table for payload-uid
entries and enforces policy at step boundaries, exactly where HTCondor
applies its SLOT_USER controls:

* wall-clock limit per payload,
* step-count limit,
* straggler detection: a payload whose step-time EWMA exceeds
  ``straggler_factor`` x the fleet median (published by the TaskRepo from all
  pilots' heartbeats) is terminated so its task can be re-queued on a
  healthier slice — tail latency control at 1000-node scale.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable


@dataclasses.dataclass
class MonitorLimits:
    max_wall: float = 120.0
    max_steps: int | None = None
    straggler_factor: float = 3.0
    min_steps_for_straggler: int = 3


@dataclasses.dataclass
class MonitorAction:
    pid: int
    kind: str          # "kill-wall" | "kill-steps" | "kill-straggler"
    detail: str


class Monitor:
    def __init__(self, proctable: ProcessTable, limits: MonitorLimits,
                 fleet_median_fn=None):
        self.proctable = proctable
        self.limits = limits
        self.fleet_median_fn = fleet_median_fn or (lambda: None)
        self.actions: list[MonitorAction] = []
        self._ewma: dict[int, float] = {}

    def scan(self, now: float | None = None) -> list[MonitorAction]:
        now = now if now is not None else time.monotonic()
        acts: list[MonitorAction] = []
        lim = self.limits
        running: set[int] = set()
        for e in self.proctable.entries(uid=PAYLOAD_UID, viewer_uid=PILOT_UID):
            if e.state != "running":
                continue
            running.add(e.pid)
            wall = now - e.started
            if wall > lim.max_wall:
                acts.append(MonitorAction(e.pid, "kill-wall",
                                          f"wall {wall:.1f}s > {lim.max_wall}s"))
            elif lim.max_steps is not None and e.steps_done > lim.max_steps:
                acts.append(MonitorAction(e.pid, "kill-steps",
                                          f"steps {e.steps_done} > {lim.max_steps}"))
            elif (e.last_step_time is not None
                  and e.steps_done >= lim.min_steps_for_straggler):
                prev = self._ewma.get(e.pid, e.last_step_time)
                ewma = 0.7 * prev + 0.3 * e.last_step_time
                self._ewma[e.pid] = ewma
                med = self.fleet_median_fn()
                if med is not None and med > 0 and ewma > lim.straggler_factor * med:
                    acts.append(MonitorAction(
                        e.pid, "kill-straggler",
                        f"ewma {ewma*1e3:.1f}ms > {lim.straggler_factor}x median {med*1e3:.1f}ms"))
        for a in acts:
            self.proctable.kill(a.pid, signaller_uid=PILOT_UID)
        self.actions.extend(acts)
        # evict EWMA state for exited/killed pids — without this, a pilot
        # running thousands of payloads leaks one float per dead pid forever
        for pid in list(self._ewma):
            if pid not in running:
                del self._ewma[pid]
        return acts
