"""SharedArena — the multi-container pod's volumes (paper §3.2, §3.5, §3.6).

Two storage areas per pilot:

* ``shared/``  — mounted into both the pilot and the payload "containers".
  The pilot stages input files here; the payload wrapper finds its *startup
  spec* here (the paper's wait-for-script loop), and writes ``exitcode.json``
  + telemetry back (the paper's exit-code relay, §3.5).
* ``private/`` — pilot-only: lease tokens, heartbeat files, credentials.
  The payload capability object simply never receives this path — the
  analogue of the volume not being mounted in the payload container.

``wipe_shared()`` is the §3.6 cleanup: between payloads the pilot clears the
shared volume; payload process cleanup itself is delegated to the executor
reset (the "container restart").
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

STARTUP_SPEC = "startup_spec.json"     # the paper's startup script path
EXITCODE_FILE = "exitcode.json"
ENV_FILE = "payload_env.json"


class SharedArena:
    def __init__(self, root: str | None = None):
        self.root = root or tempfile.mkdtemp(prefix="pilot_arena_")
        self.shared = os.path.join(self.root, "shared")
        self.private = os.path.join(self.root, "private")
        os.makedirs(self.shared, exist_ok=True)
        os.makedirs(self.private, exist_ok=True)
        # in-process fast path for the payload's wait-for-spec loop: publish
        # sets the event so a co-resident waiter wakes instantly instead of
        # polling the file (the file stays authoritative — an out-of-process
        # waiter still sees the atomic rename).
        self._spec_event = threading.Event()
        self._last_env_blob: bytes | None = None
        # in-memory mirrors of the spec/exit files for co-resident readers
        # (the page-cache analogue): the files are always written and stay
        # authoritative for out-of-process readers
        self._last_spec: dict | None = None
        self._last_exit: dict | None = None

    # ---- pilot-side staging (step (b)/(c) of the lifecycle) ---------------

    def stage_file(self, name: str, data: bytes) -> str:
        path = os.path.join(self.shared, name)
        if "/" in name:                   # top-level files need no makedirs
            os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
        return path

    def write_env(self, env: dict) -> str:
        blob = json.dumps(env).encode()
        path = os.path.join(self.shared, ENV_FILE)
        if blob == self._last_env_blob:   # unchanged since last write — the
            return path                   # common case for multi-payload pilots
        path = self.stage_file(ENV_FILE, blob)
        self._last_env_blob = blob
        return path

    def publish_startup_spec(self, spec: dict) -> str:
        """Publishing the spec is what releases the payload container's
        wait-loop — write must be atomic (tmp+rename)."""
        path = self.stage_file(STARTUP_SPEC, json.dumps(spec).encode())
        self._last_spec = dict(spec)
        self._spec_event.set()
        return path

    # ---- payload-side (wrapper) -------------------------------------------

    def wait_for_startup_spec(self, timeout: float = 30.0,
                              poll: float = 0.01) -> dict | None:
        """The payload container's wait-for-script loop (paper §3.3).

        A co-resident publisher sets the spec event, so the in-process wake
        is immediate; each event wait is still bounded by ``poll`` so a
        publisher holding a *different* SharedArena over the same root (the
        two-process deployment) is noticed at the seed's poll cadence."""
        path = os.path.join(self.shared, STARTUP_SPEC)
        deadline = time.monotonic() + timeout
        while True:
            if self._last_spec is not None:
                return self._last_spec
            if os.path.exists(path):      # published by another process
                with open(path) as f:
                    return json.load(f)
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            self._spec_event.wait(timeout=min(poll, remaining))

    def read_env(self) -> dict:
        path = os.path.join(self.shared, ENV_FILE)
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
        return {}

    def report_exit(self, exitcode: int, telemetry: dict | None = None):
        info = {"exitcode": exitcode, "telemetry": telemetry or {},
                "time": time.time()}
        self.stage_file(EXITCODE_FILE, json.dumps(info).encode())
        self._last_exit = info

    # ---- pilot-side collection (step (e)) ----------------------------------

    def read_exit(self) -> dict | None:
        if self._last_exit is not None:
            return self._last_exit
        path = os.path.join(self.shared, EXITCODE_FILE)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def shared_files(self) -> list[str]:
        out = []
        for base, _, files in os.walk(self.shared):
            for f in files:
                out.append(os.path.relpath(os.path.join(base, f), self.shared))
        return sorted(out)

    def output_files(self, prefix: str = "out") -> dict[str, bytes]:
        """Collect payload outputs without walking the whole shared tree —
        the common no-outputs case is a single stat."""
        base = os.path.join(self.shared, prefix)
        out: dict[str, bytes] = {}
        if not os.path.isdir(base):
            return out
        for root, _, files in os.walk(base):
            for f in files:
                p = os.path.join(root, f)
                with open(p, "rb") as fh:
                    out[os.path.relpath(p, self.shared)] = fh.read()
        return out

    # ---- cleanup (step (f)/(h)) --------------------------------------------

    def wipe_shared(self):
        self._spec_event.clear()          # next waiter blocks until republish
        self._last_env_blob = None
        self._last_spec = None
        self._last_exit = None
        with os.scandir(self.shared) as it:
            entries = list(it)
        for e in entries:                 # unlink in place: cheaper than
            if e.is_dir(follow_symlinks=False):       # rmtree + mkdir
                shutil.rmtree(e.path, ignore_errors=True)
            else:
                try:
                    os.unlink(e.path)
                except OSError:
                    pass

    def destroy(self):
        shutil.rmtree(self.root, ignore_errors=True)
