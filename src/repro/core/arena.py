"""SharedArena — the multi-container pod's volumes (paper §3.2, §3.5, §3.6).

Two storage areas per pilot:

* ``shared/``  — mounted into both the pilot and the payload "containers".
  The pilot stages input files here; the payload wrapper finds its *startup
  spec* here (the paper's wait-for-script loop), and writes ``exitcode.json``
  + telemetry back (the paper's exit-code relay, §3.5).
* ``private/`` — pilot-only: lease tokens, heartbeat files, credentials.
  The payload capability object simply never receives this path — the
  analogue of the volume not being mounted in the payload container.

``wipe_shared()`` is the §3.6 cleanup: between payloads the pilot clears the
shared volume; payload process cleanup itself is delegated to the executor
reset (the "container restart").
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

STARTUP_SPEC = "startup_spec.json"     # the paper's startup script path
EXITCODE_FILE = "exitcode.json"
ENV_FILE = "payload_env.json"


class SharedArena:
    def __init__(self, root: str | None = None):
        self.root = root or tempfile.mkdtemp(prefix="pilot_arena_")
        self.shared = os.path.join(self.root, "shared")
        self.private = os.path.join(self.root, "private")
        os.makedirs(self.shared, exist_ok=True)
        os.makedirs(self.private, exist_ok=True)

    # ---- pilot-side staging (step (b)/(c) of the lifecycle) ---------------

    def stage_file(self, name: str, data: bytes) -> str:
        path = os.path.join(self.shared, name)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
        return path

    def write_env(self, env: dict) -> str:
        return self.stage_file(ENV_FILE, json.dumps(env).encode())

    def publish_startup_spec(self, spec: dict) -> str:
        """Publishing the spec is what releases the payload container's
        wait-loop — write must be atomic (tmp+rename)."""
        return self.stage_file(STARTUP_SPEC, json.dumps(spec).encode())

    # ---- payload-side (wrapper) -------------------------------------------

    def wait_for_startup_spec(self, timeout: float = 30.0,
                              poll: float = 0.01) -> dict | None:
        """The payload container's shell wait-loop (paper §3.3)."""
        path = os.path.join(self.shared, STARTUP_SPEC)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if os.path.exists(path):
                with open(path) as f:
                    return json.load(f)
            time.sleep(poll)
        return None

    def read_env(self) -> dict:
        path = os.path.join(self.shared, ENV_FILE)
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
        return {}

    def report_exit(self, exitcode: int, telemetry: dict | None = None):
        self.stage_file(EXITCODE_FILE, json.dumps(
            {"exitcode": exitcode, "telemetry": telemetry or {},
             "time": time.time()}).encode())

    # ---- pilot-side collection (step (e)) ----------------------------------

    def read_exit(self) -> dict | None:
        path = os.path.join(self.shared, EXITCODE_FILE)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def shared_files(self) -> list[str]:
        out = []
        for base, _, files in os.walk(self.shared):
            for f in files:
                out.append(os.path.relpath(os.path.join(base, f), self.shared))
        return sorted(out)

    # ---- cleanup (step (f)/(h)) --------------------------------------------

    def wipe_shared(self):
        shutil.rmtree(self.shared, ignore_errors=True)
        os.makedirs(self.shared, exist_ok=True)

    def destroy(self):
        shutil.rmtree(self.root, ignore_errors=True)
