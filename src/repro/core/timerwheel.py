"""TimerWheel — one deadline-heap timer thread for the whole control plane.

The event-driven refactor removes the per-pilot sleep loops; everything that
still needs a clock (lease expiry, lease renewal, the monitor's wall/straggler
tick, telemetry heartbeats) is a *timer* on a shared wheel instead.  One
thread services a heap of deadlines: it sleeps exactly until the earliest
deadline (interruptible by new, earlier timers) and fires callbacks on the
wheel thread.  With N pilots the process holds one timer thread, not N
polling loops — control-plane CPU stays flat as the fleet grows.

Callbacks must be short and non-blocking (they share one thread); anything
heavy should set an event and let the owner's thread do the work.

A raising callback must never be *silent*: the wheel services the lease
reaper and the payload monitor, so a swallowed exception there would turn
off lease expiry — the exact failure the fleet's requeue-on-pilot-death
story depends on never happening.  Every callback error is recorded on the
wheel's error ledger (``errors`` keeps the most recent ``(timer name,
exception)`` pairs, ``error_count`` counts them all) and surfaced through
:meth:`TimerWheel.stats`; a periodic timer that raised stays scheduled.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Callable

from repro.analysis.locks import (
    RANK_WHEEL,
    audit_callback,
    make_condition,
    make_lock,
)


class Timer:
    """Handle for a scheduled callback.  ``cancel()`` is lazy: the wheel
    drops cancelled entries when they surface at the top of the heap."""

    __slots__ = ("fn", "deadline", "interval", "cancelled", "name")

    def __init__(self, fn: Callable[[], None], deadline: float,
                 interval: float | None, name: str | None = None):
        self.fn = fn
        self.deadline = deadline
        self.interval = interval          # None -> one-shot
        self.cancelled = False
        self.name = name or getattr(fn, "__qualname__", repr(fn))

    def cancel(self):
        self.cancelled = True


class TimerWheel:
    def __init__(self, name: str = "timer-wheel"):
        self._cond = make_condition(name=f"timerwheel[{name}]", rank=RANK_WHEEL)
        self._heap: list[tuple[float, int, Timer]] = []
        self._seq = itertools.count()
        self._thread: threading.Thread | None = None
        self._name = name
        self.fired = 0                    # observability: callbacks run
        self.error_count = 0              # callbacks that raised (total)
        self.errors: deque[tuple[str, Exception]] = deque(maxlen=32)

    # ---- scheduling -------------------------------------------------------

    def call_later(self, delay: float, fn: Callable[[], None],
                   name: str | None = None) -> Timer:
        return self._push(Timer(fn, time.monotonic() + max(delay, 0.0), None,
                                name))

    def call_at(self, deadline: float, fn: Callable[[], None],
                name: str | None = None) -> Timer:
        return self._push(Timer(fn, deadline, None, name))

    def call_periodic(self, interval: float, fn: Callable[[], None],
                      name: str | None = None) -> Timer:
        if interval <= 0:
            raise ValueError("periodic interval must be > 0")
        return self._push(Timer(fn, time.monotonic() + interval, interval,
                                name))

    def _push(self, t: Timer) -> Timer:
        with self._cond:
            is_earliest = not self._heap or t.deadline < self._heap[0][0]
            heapq.heappush(self._heap, (t.deadline, next(self._seq), t))
            self._ensure_thread()
            if is_earliest:               # only interrupt the service thread
                self._cond.notify()       # when its wait deadline moves up
        return t

    # ---- service thread ---------------------------------------------------

    def _ensure_thread(self):
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=self._name)
            self._thread.start()

    def _run(self):
        while True:
            with self._cond:
                while True:
                    if not self._heap:
                        self._cond.wait()
                        continue
                    deadline, _, timer = self._heap[0]
                    if timer.cancelled:
                        heapq.heappop(self._heap)
                        continue
                    wait = deadline - time.monotonic()
                    if wait <= 0:
                        heapq.heappop(self._heap)
                        break
                    self._cond.wait(timeout=wait)
            try:
                # Callbacks run with NO wheel lock held; the audit guard
                # proves that invariant (and catches any future regression).
                audit_callback(f"timerwheel:{timer.name}")
                timer.fn()
            except Exception as e:        # noqa: BLE001 — timers never kill the
                # wheel, but they must not die silently either: a crashing
                # lease reaper would disable lease expiry fleet-wide
                with self._cond:          # stats() snapshots under the same
                    self.errors.append((timer.name, e))    # lock
                    self.error_count += 1
            self.fired += 1
            if timer.interval is not None and not timer.cancelled:
                timer.deadline = time.monotonic() + timer.interval
                self._push(timer)

    # ---- observability ----------------------------------------------------

    def stats(self) -> dict:
        """Fired/error accounting; ``last_errors`` names the timers whose
        callbacks raised so a disabled lease reaper is visible, not silent."""
        with self._cond:                  # snapshot vs concurrent appends
            errors = list(self.errors)
            count = self.error_count
        return {
            "fired": self.fired,
            "errors": count,
            "last_errors": [(n, f"{type(e).__name__}: {e}")
                            for n, e in errors],
        }


_default_wheel: TimerWheel | None = None
_default_lock = make_lock("timerwheel.default-registry")


def shared_wheel() -> TimerWheel:
    """Process-wide wheel: TaskRepo and all Pilots share one timer thread."""
    global _default_wheel
    with _default_lock:
        if _default_wheel is None:
            _default_wheel = TimerWheel("control-plane-timer")
        return _default_wheel
