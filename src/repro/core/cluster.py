"""ClusterSim — the resource provider (Kubernetes / kubelet analogue).

Grants *slices* (pods' worth of devices) to pilot jobs, injects node
failures, and supports elastic grow/shrink.  The simulation is deliberately
thin: its job is to exercise the pilot system's provisioning-facing
contracts (grant -> run -> release; hard failure -> lease expiry -> re-queue;
membership change -> remesh plan) so they are testable without a cluster.

The :class:`Fleet` layer manages N pilots as one unit — spawn, scale up,
graceful scale-down, await-drained — all notification-driven:
``run_until_drained``/``Fleet.await_drained`` block on the repo's drain
event instead of polling ``stats()`` on a timer.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Optional

import jax

from repro.analysis.locks import make_lock
from repro.core.images import ExecutableRegistry
from repro.core.pilot import Pilot, PilotConfig, TERMINAL_STATES
from repro.core.taskrepo import TaskRepo
from repro.runtime.elastic import plan_remesh
from repro.runtime.mesh import MeshSpec


def _pilot_record(p: "Pilot") -> dict:
    """What survives a reaped pilot: identity, the full state-machine path,
    and the accounting the autoscaler benchmarks charge against."""
    return {
        "pilot_id": p.pilot_id,
        "slice_id": p.slice.slice_id,
        "state": p.state,
        "state_log": list(p.state_log),
        "payloads_run": p.payloads_run,
        "error": p.error,
        "pilot_seconds": p.pilot_seconds(),
    }


@dataclasses.dataclass
class PilotSlice:
    slice_id: int
    devices: list
    labels: dict = dataclasses.field(default_factory=dict)
    mesh: Optional[object] = None
    released: bool = False

    def release(self):
        self.released = True


class ClusterSim:
    def __init__(self, repo: TaskRepo | None = None,
                 registry: ExecutableRegistry | None = None):
        self.repo = repo or TaskRepo()
        self.registry = registry or ExecutableRegistry()
        self._ids = itertools.count(1)
        self._lock = make_lock("cluster.sim")
        self.slices: dict[int, PilotSlice] = {}
        self.pilots: dict[int, Pilot] = {}
        # reaped (terminal, thread-joined) pilots: bounded, state_log kept
        self.pilot_history: deque[dict] = deque(maxlen=512)

    # ---- provisioning -------------------------------------------------------

    def provision(self, n_slices: int = 1, *, labels: dict | None = None,
                  mesh=None) -> list[PilotSlice]:
        devs = jax.devices()
        out = []
        with self._lock:
            for _ in range(n_slices):
                sid = next(self._ids)
                s = PilotSlice(slice_id=sid, devices=list(devs),
                               labels=dict(labels or {}), mesh=mesh)
                self.slices[sid] = s
                out.append(s)
        return out

    def spawn_pilot(self, slice_: PilotSlice,
                    config: PilotConfig | None = None) -> Pilot:
        p = Pilot(slice_, self.repo, self.registry, config)
        with self._lock:
            self.pilots[slice_.slice_id] = p
        p.start_async()
        return p

    def spawn_fleet(self, n_pilots: int, config: PilotConfig | None = None,
                    *, labels: dict | None = None, mesh=None) -> "Fleet":
        """Provision n slices and start a pilot on each, as one Fleet."""
        fleet = Fleet(self, config, labels=labels, mesh=mesh)
        fleet.scale_up(n_pilots)
        return fleet

    # ---- failure injection / drain -------------------------------------------

    def fail_node(self, slice_id: int):
        """Hard node loss: the pilot thread aborts without cleanup AND the
        payload processes die with the node; the lease expires and the repo
        re-queues the task.  For a SERVING pilot the same mechanism cascades
        one level down: the dead server stops renewing its per-request
        leases, so the fleet pool's reaper requeues its in-flight requests
        onto surviving servers (the headline fleet-serve scenario)."""
        from repro.core.proctable import PAYLOAD_UID
        with self._lock:
            p = self.pilots.get(slice_id)
        if p:
            p.fail()
            p.proctable.kill_uid(PAYLOAD_UID)

    def fail_pilot(self, pilot_id: str) -> bool:
        """:meth:`fail_node` addressed by pilot_id — the identity fault
        drivers (chaos controller, fleet-serve kill loop) actually hold,
        since slice ids are an internal detail of provisioning."""
        with self._lock:
            target = next((sid for sid, p in self.pilots.items()
                           if p.pilot_id == pilot_id), None)
        if target is None:
            return False
        self.fail_node(target)
        return True

    def drain(self, slice_id: int):
        with self._lock:
            p = self.pilots.get(slice_id)
        if p:
            p.drain()

    # ---- elasticity ------------------------------------------------------------

    def reap_pilots(self) -> int:
        """Prune pilots that reached a terminal state AND whose thread has
        exited.  Without reaping, ``pilots`` (and every ``live_pilots``
        scan) grows without bound across scale_up/scale_down cycles; the
        reaped pilots' ``state_log`` survives in the bounded
        ``pilot_history``."""
        with self._lock:
            dead = [(sid, p) for sid, p in self.pilots.items() if p.done()]
            for sid, p in dead:
                del self.pilots[sid]
                self.pilot_history.append(_pilot_record(p))
        return len(dead)

    def live_pilots(self) -> list[Pilot]:
        self.reap_pilots()
        with self._lock:
            return [p for p in self.pilots.values()
                    if p.state not in TERMINAL_STATES]

    def remesh_plan(self, model_parallel: int, global_batch: int,
                    old: MeshSpec | None = None):
        return plan_remesh(old, len(self.live_pilots()), model_parallel,
                           global_batch)

    # ---- convenience -------------------------------------------------------------

    def run_until_drained(self, timeout: float = 60.0,
                          poll: float | None = None) -> bool:
        """Block on the repo's drain event (queued == leased == 0).

        Lease expiry is serviced by the repo's deadline-heap timer, so there
        is nothing to poll; ``poll`` is kept for API compatibility and
        ignored.
        """
        return self.repo.wait_drained(timeout)

    def join_all(self, timeout: float = 10.0):
        for p in list(self.pilots.values()):
            p.join(timeout)


class Fleet:
    """A managed group of pilots over one ClusterSim (paper §4 at scale:
    provisioning N pods is one autoscaler action, not N manual spawns)."""

    def __init__(self, sim: ClusterSim, config: PilotConfig | None = None,
                 *, labels: dict | None = None, mesh=None):
        self.sim = sim
        self.config = config
        self.labels = labels
        self.mesh = mesh
        self._lock = make_lock("cluster.fleet")  # members churns from autoscaler
        self.members: list[Pilot] = []    # and driver threads concurrently
        self.history: deque[dict] = deque(maxlen=512)   # reaped members
        self._retired_seconds = 0.0

    # ---- scaling ------------------------------------------------------------

    def scale_up(self, n: int) -> list[Pilot]:
        """Provision n fresh slices and start a pilot on each.  During a
        fleet serve this is the join-mid-trace path: pair it with
        :meth:`submit_servers` and the new pilots lease into the request
        pool alongside the survivors."""
        started = []
        for s in self.sim.provision(n, labels=self.labels, mesh=self.mesh):
            started.append(self.sim.spawn_pilot(s, self.config))
        with self._lock:
            self.members.extend(started)
        return started

    def submit_servers(self, image, pool_name: str, *, n: int | None = None,
                       n_steps: int = 200_000, max_wall: float = 600.0,
                       spec: dict | None = None, **task_kw) -> list[int]:
        """Submit one serve-server task per pilot (default: one per live
        member).  Each server late-binds an engine onto its pilot's slice
        and leases requests from the named
        :class:`~repro.serving.dispatch.FleetDispatcher` pool — the fleet
        analog of one trace-carrying serve task.  ``spec`` merges extra
        engine geometry (``slots``/``max_len``/``kv``/...) into the startup
        spec."""
        n = n if n is not None else max(1, self.size())
        return [self.sim.repo.submit(
            image, n_steps=n_steps, max_wall=max_wall,
            payload_spec={"dispatch": pool_name, **(spec or {})}, **task_kw)
            for _ in range(n)]

    def scale_down(self, n: int) -> list[Pilot]:
        """Gracefully drain the n most recently started live pilots.
        Pilots already draining don't count — back-to-back calls shed
        distinct pilots.  A draining SERVING pilot releases its leased
        requests back to the pool before exit (no lease-TTL wait): see
        ``Pilot.drain`` / ``wrapper._fleet_serve_loop``."""
        with self._lock:
            members = list(self.members)
        victims = [p for p in reversed(members)
                   if p.state not in TERMINAL_STATES
                   and not p.drain_flag.is_set()][:n]
        for p in victims:
            p.drain()
        return victims

    def reap(self) -> int:
        """Move terminal, thread-joined members into the bounded history
        (state_log preserved) and prune the ClusterSim registry too.  Runs
        implicitly on every ``live()``/``size()`` scan, so scale churn never
        grows the member list without bound."""
        with self._lock:
            done = [p for p in self.members if p.done()]
            for p in done:
                self.members.remove(p)
                self.history.append(_pilot_record(p))
                self._retired_seconds += p.pilot_seconds()
        self.sim.reap_pilots()
        return len(done)

    def live(self) -> list[Pilot]:
        self.reap()
        with self._lock:
            return [p for p in self.members if p.state not in TERMINAL_STATES]

    def size(self) -> int:
        return len(self.live())

    def draining(self) -> int:
        """Live members already asked to drain — capacity that is still
        counted by ``size()`` but is on its way out.  The autoscaler sizes
        against ``size() - draining()`` so a mid-drain victim is never
        double-counted (back-to-back scale_downs would overshoot)."""
        with self._lock:
            return sum(1 for p in self.members
                       if p.drain_flag.is_set()
                       and p.state not in TERMINAL_STATES)

    def pilot_seconds(self, now: float | None = None) -> float:
        """Total slice-holding wall time across the fleet's whole life —
        the resource-consumption metric autoscaling is judged on (reaped
        members included)."""
        now = time.monotonic() if now is None else now
        with self._lock:
            total = self._retired_seconds
            members = list(self.members)
        return total + sum(p.pilot_seconds(now) for p in members)

    # ---- lifecycle ----------------------------------------------------------

    def await_drained(self, timeout: float = 60.0) -> bool:
        """Block until the repo has nothing queued or leased (drain event)."""
        return self.sim.repo.wait_drained(timeout)

    def drain_all(self):
        with self._lock:
            members = list(self.members)
        for p in members:
            p.drain()

    def join_all(self, timeout: float = 10.0):
        with self._lock:
            members = list(self.members)
        for p in members:
            p.join(timeout)
