"""ClusterSim — the resource provider (Kubernetes / kubelet analogue).

Grants *slices* (pods' worth of devices) to pilot jobs, injects node
failures, and supports elastic grow/shrink.  The simulation is deliberately
thin: its job is to exercise the pilot system's provisioning-facing
contracts (grant -> run -> release; hard failure -> lease expiry -> re-queue;
membership change -> remesh plan) so they are testable without a cluster.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Optional

import jax

from repro.core.images import ExecutableRegistry
from repro.core.pilot import Pilot, PilotConfig
from repro.core.taskrepo import TaskRepo
from repro.runtime.elastic import plan_remesh
from repro.runtime.mesh import MeshSpec


@dataclasses.dataclass
class PilotSlice:
    slice_id: int
    devices: list
    labels: dict = dataclasses.field(default_factory=dict)
    mesh: Optional[object] = None
    released: bool = False

    def release(self):
        self.released = True


class ClusterSim:
    def __init__(self, repo: TaskRepo | None = None,
                 registry: ExecutableRegistry | None = None):
        self.repo = repo or TaskRepo()
        self.registry = registry or ExecutableRegistry()
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self.slices: dict[int, PilotSlice] = {}
        self.pilots: dict[int, Pilot] = {}

    # ---- provisioning -------------------------------------------------------

    def provision(self, n_slices: int = 1, *, labels: dict | None = None,
                  mesh=None) -> list[PilotSlice]:
        devs = jax.devices()
        out = []
        with self._lock:
            for _ in range(n_slices):
                sid = next(self._ids)
                s = PilotSlice(slice_id=sid, devices=list(devs),
                               labels=dict(labels or {}), mesh=mesh)
                self.slices[sid] = s
                out.append(s)
        return out

    def spawn_pilot(self, slice_: PilotSlice,
                    config: PilotConfig | None = None) -> Pilot:
        p = Pilot(slice_, self.repo, self.registry, config)
        with self._lock:
            self.pilots[slice_.slice_id] = p
        p.start_async()
        return p

    # ---- failure injection / drain -------------------------------------------

    def fail_node(self, slice_id: int):
        """Hard node loss: the pilot thread aborts without cleanup AND the
        payload processes die with the node; the lease expires and the repo
        re-queues the task."""
        from repro.core.proctable import PAYLOAD_UID
        with self._lock:
            p = self.pilots.get(slice_id)
        if p:
            p.fail_flag.set()
            p.proctable.kill_uid(PAYLOAD_UID)

    def drain(self, slice_id: int):
        with self._lock:
            p = self.pilots.get(slice_id)
        if p:
            p.drain_flag.set()

    # ---- elasticity ------------------------------------------------------------

    def live_pilots(self) -> list[Pilot]:
        with self._lock:
            return [p for p in self.pilots.values()
                    if p.state not in ("terminated", "failed")]

    def remesh_plan(self, model_parallel: int, global_batch: int,
                    old: MeshSpec | None = None):
        return plan_remesh(old, len(self.live_pilots()), model_parallel,
                           global_batch)

    # ---- convenience -------------------------------------------------------------

    def run_until_drained(self, timeout: float = 60.0, poll: float = 0.05) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.repo.reap_leases()
            if self.repo.drain_done():
                return True
            time.sleep(poll)
        return False

    def join_all(self, timeout: float = 10.0):
        for p in list(self.pilots.values()):
            p.join(timeout)
