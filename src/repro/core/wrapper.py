"""PayloadWrapper — the startup wrapper inside the payload container (§3.5).

Responsibilities, mirroring the paper:

1. runs as fake-root inside the payload container: it may set up the
   environment and register processes, but it *drops privileges* before
   invoking user code — the user step loop only ever sees a
   :class:`PayloadCapability` with the payload uid and the shared arena
   path (never the pilot's private area or the pod-patch capability);
2. sources the payload environment from the shared volume;
3. runs the payload and relays its exit code + telemetry back through
   ``exitcode.json`` on the shared volume (there is no parent-child process
   relationship to propagate it through);
4. heartbeats per step so the pilot's monitor can meter progress and
   enforce limits at step boundaries.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.arena import SharedArena
from repro.core.proctable import PAYLOAD_UID, ProcessTable


@dataclasses.dataclass(frozen=True)
class PayloadCapability:
    """What user code gets after the privilege drop: its uid and the shared
    volume path.  No pilot token, no private volume, no pod patch rights."""
    uid: int
    shared_dir: str


_KEY_CACHE: dict[int, object] = {}


def _seed_key(seed: int):
    """jax.random.key costs ~3 ms of dispatch per call — dominant in the
    control-plane cost of a tiny payload.  Keys are pure functions of the
    seed, so memoize (bounded: payload seeds are few)."""
    k = _KEY_CACHE.get(seed)
    if k is None:
        if len(_KEY_CACHE) > 128:
            _KEY_CACHE.clear()
        k = _KEY_CACHE[seed] = jax.random.key(seed)
    return k


def run_wrapper(arena: SharedArena, proctable: ProcessTable, exe, spec: dict):
    """Execute one payload under the payload uid.  Never raises: every
    outcome becomes an exit code in the arena (the paper's relay)."""
    # env arrives inside the startup spec (the pilot path) or, for direct
    # arena users, in the standalone env file on the shared volume (§3.5)
    env = spec.get("env")
    if env is None:
        env = arena.read_env()
    entry = proctable.register(PAYLOAD_UID, f"payload:{exe.image.arch}:{exe.image.mode}")
    cap = PayloadCapability(uid=PAYLOAD_UID, shared_dir=arena.shared)
    t_start = time.monotonic()
    telemetry: dict = {"steps": 0, "mode": exe.image.mode,
                       "arch": exe.image.arch, "step_times": []}
    exitcode = 0
    try:
        key = _seed_key(int(env.get("seed", 0)))
        n_steps = int(spec.get("n_steps", 1))
        if exe.image.mode == "noop":
            exe.fn(exe.make_inputs(key))
            telemetry["steps"] = 1
        elif exe.image.mode == "train":
            exitcode = _train_loop(exe, key, n_steps, entry, proctable,
                                   telemetry, spec, arena, cap)
        elif exe.image.mode == "prefill":
            params, batch = exe.make_inputs(key)
            t0 = time.monotonic()
            logits, cache = exe.fn(params, batch)
            jax.block_until_ready(logits)
            dt = time.monotonic() - t0
            proctable.heartbeat(entry.pid, dt)
            telemetry["steps"] = 1
            telemetry["step_times"].append(dt)
            if not np.isfinite(np.asarray(logits, np.float32)).all():
                exitcode = 3
        elif exe.image.mode == "serve":
            exitcode = _serve_loop(exe, key, n_steps, entry, proctable,
                                   telemetry, spec)
        else:                                           # decode
            params, state = exe.make_inputs(key)
            for i in range(n_steps):
                if entry.stop.is_set():
                    exitcode = 143                      # SIGTERM-by-pilot
                    break
                t0 = time.monotonic()
                logits, state = exe.fn(params, state)
                jax.block_until_ready(logits)
                dt = time.monotonic() - t0
                proctable.heartbeat(entry.pid, dt)
                telemetry["steps"] = i + 1
                telemetry["step_times"].append(dt)
    except Exception as e:                               # noqa: BLE001
        exitcode = 1
        telemetry["error"] = f"{type(e).__name__}: {e}"
    telemetry["wall"] = time.monotonic() - t_start
    telemetry["step_times"] = telemetry["step_times"][-16:]
    proctable.mark_exited(entry.pid, exitcode)
    arena.report_exit(exitcode, telemetry)
    return exitcode


def _serve_loop(exe, key, n_steps, entry, proctable, telemetry, spec) -> int:
    """Serve payload: a continuous-batching inference server late-bound onto
    the slice.

    Two request sources, selected by the startup spec:

    * ``trace`` — the single-engine path: JSON dicts ``{"rid", "prompt":
      [ints], "max_new_tokens", "at_step"}``; a request is admitted once the
      engine has ticked ``at_step`` times (staggered arrivals).
    * ``dispatch`` — the FLEET path: the spec names a
      :class:`~repro.serving.dispatch.FleetDispatcher` pool and the server
      leases requests out of it instead of owning a static trace; per-
      request progress piggybacks on lease renewal every tick, so a server
      that dies simply stops renewing and its in-flight requests requeue
      onto survivors (see ``_fleet_serve_loop``).

    ``n_steps`` bounds the tick count — the lease/budget contract serve
    shares with train.  The engine's decode loop is device-resident (one
    device→host transfer per step); each tick heartbeats the proctable so
    the pilot's monitor meters serve progress exactly as it meters train
    steps.
    """
    params = exe.make_inputs(key)
    kv_kw = {k: spec[k] for k in ("kv", "prefill", "prefill_chunk",
                                  "num_blocks", "block_size",
                                  "prefix_sharing", "spec", "spec_k",
                                  "mesh_shape", "role")
             if spec.get(k) is not None}
    eng = exe.fn(params, slots=spec.get("slots"),
                 max_len=spec.get("max_len"), **kv_kw)
    if spec.get("dispatch"):
        return _fleet_serve_loop(eng, spec, n_steps, entry, proctable,
                                 telemetry)

    def on_tick(tick, dt):
        if entry.stop.is_set():
            return False                                # SIGTERM-by-pilot
        proctable.heartbeat(entry.pid, dt)
        telemetry["steps"] = tick
        telemetry["step_times"].append(dt)
        # live cache-pressure sample rides every heartbeat, so the pilot's
        # monitor sees KV pressure mid-run, not only at exit
        telemetry["serve_live"] = eng.kv_pressure()
        return True

    stats = eng.run_trace(spec.get("trace") or [], max_ticks=n_steps,
                          on_tick=on_tick)
    if entry.stop.is_set():
        return 143
    # cache pressure rides along: the pilot's heartbeat consumer sees how
    # hot the slot-sized claim is running and what the prefix cache saves
    telemetry["serve"] = {k: stats[k] for k in _SERVE_STAT_KEYS}
    telemetry["tokens"] = {str(r.rid): r.tokens for r in eng.done.values()}
    return 0


_SERVE_STAT_KEYS = (
    "completed", "decode_steps", "tokens_decoded", "slot_utilization",
    "idle_slot_steps", "d2h_transfers", "tok_per_s",
    "ttft_p50_s", "ttft_p99_s",
    "kv", "kv_memory_utilization", "kv_peak_live_tokens",
    "kv_capacity_tokens", "prefix_hit_rate", "prefill_chunks",
    "blocked_admissions",
    "spec", "spec_fallback_reason", "acceptance_rate", "tokens_per_step",
    "draft_overhead_s",
    "mesh_shape", "mesh_devices", "slots",
    "kv_pool_bytes", "kv_pool_bytes_per_device",
    "role", "prefills_exported", "handoffs_imported")


def _fleet_serve_loop(eng, spec, n_steps, entry, proctable, telemetry) -> int:
    """Fleet serve: lease requests from the pool named in the startup spec
    instead of replaying a static trace.

    Per tick: top up free slots from the pool (the fetch parks on the pool
    condition when the engine is idle, so a requeued request wakes the
    server immediately), one engine step, report completions (first
    completion wins at the pool), then renew every in-flight lease with its
    progress.  A renewal the pool refuses means the lease expired and moved
    elsewhere — the slot is cancelled rather than racing a replay it cannot
    win.

    Death semantics: when the stop event fires (node loss / SIGTERM) the
    loop returns WITHOUT releasing anything — a dead server cannot clean up,
    and the pool's lease-expiry reaper requeueing its in-flight requests is
    exactly the failure path this payload exists to exercise.  A graceful
    end (tick budget, pool closed, or the pilot's DRAIN event — the
    autoscaler's scale-down path) hands unfinished requests straight back
    instead: survivors requeue them immediately, no lease-TTL wait.

    Each tick also reports the engine's KV-pressure sample to the pool
    (``report_telemetry``), which the autoscaler reads via
    ``pool_pressure`` — kv_memory_utilization / blocked_admissions are
    scale-up signals a queue-depth-only policy would miss."""
    from repro.core import chaos
    from repro.serving import dispatch as fleet_dispatch
    from repro.serving.engine import Request

    pool = fleet_dispatch.get_pool(spec["dispatch"])
    if pool is None:
        raise RuntimeError(f"fleet pool {spec['dispatch']!r} is not "
                           f"registered in this process")
    server_id = ((spec.get("env") or {}).get("pilot")
                 or f"server-{spec.get('task_id', id(eng))}")
    labels = spec.get("server_labels") or {}
    # stage every admission bucket AND the whole admit/decode/evict install
    # path before taking the first lease: a mid-serve compile stalls
    # renewals past the lease TTL and thrashes requests between servers
    # that are all compiling.  Factory-shared jit wrappers and the
    # process-global eager-op cache make this nearly free for every server
    # after the first on the same image.
    eng.warm_admission()
    eng.warm_install()
    # labels carry the server's pool role ({"pool": "prefill"|"decode"}) so
    # pool_pressure() can report per-label telemetry instead of blending
    # prefill TTFT with decode TPOT across a mixed fleet
    pool.announce(server_id, labels=labels)
    inflight: dict[int, Request] = {}
    fetched = completed_here = released = 0
    decoded = tick = 0
    t_start = time.monotonic()
    while tick < n_steps:
        if entry.stop.is_set():
            return 143                   # died mid-serve: leases just expire
        if pool.closed.is_set():
            break
        if entry.drain.is_set():
            break        # scale-down: wind down NOW — leased work is
                         # released below, not left to wait out its TTL
        # chaos drills (no-op dict probe when no controller is installed):
        # a STALLED payload freezes — no fetch, no step, no completions —
        # but its lease renewals keep flowing with frozen progress, which
        # is exactly the gray failure only the progress watchdog can see
        site = chaos.site(server_id)
        stalled = site is not None and site.stalled()
        cut = site is not None and site.partitioned()
        if stalled:
            if inflight:
                pool.renew(server_id, {rid: len(r.tokens)
                                       for rid, r in inflight.items()})
            time.sleep(0.005)
            tick += 1
            continue
        # _live already counts mid-admission (_jobs) requests, so this is
        # every admitted-or-queued request exactly once
        want = eng.slots - (len(eng._live) + len(eng.queue))
        if want > 0 and not cut and not pool.finished():
            idle = not any(m.active for m in eng.slot_meta) and not eng._jobs
            for e in pool.fetch(server_id, max_n=want,
                                timeout=0.05 if idle else 0.0,
                                labels=labels, cancel=entry.stop.is_set):
                if (site is not None and e.get("poison")
                        and site.poison_lethal()):
                    # poison request: detonates on fetch, killing this
                    # pilot — the lease is never released; it expires and
                    # the pool's blast-radius accounting takes over
                    site.trip_poison(int(e["rid"]))
                    return 143
                req = Request(
                    rid=int(e["rid"]),
                    prompt=np.asarray(e["prompt"], np.int32),
                    max_new_tokens=int(e.get("max_new_tokens", 16)),
                    submitted=float(e.get("submitted_s", time.monotonic())),
                    handoff=e.get("handoff"))
                if req.rid in inflight:
                    # the pool re-leased a rid this server still holds
                    # locally: its lease expired mid-partition and looped
                    # back before this tick's renew could reveal the loss.
                    # Purge the stale copy — pairing the fresh Request
                    # with the old engine result would commit truncated
                    # tokens (and two live slots under one rid is worse)
                    eng.cancel(req.rid)
                    inflight.pop(req.rid, None)
                eng.done.pop(req.rid, None)    # stale result of a lost lease
                try:
                    eng.submit(req)
                except ValueError:
                    pool.reject(server_id, req.rid)   # can NEVER fit here
                    continue
                inflight[req.rid] = req
                fetched += 1
        t0 = time.monotonic()
        decoded += eng.step()
        dt = time.monotonic() - t0
        if site is not None:
            slow = site.slow_factor()
            if slow > 1.0:               # straggler: inflate the step time
                time.sleep(dt * (slow - 1.0))
                dt = dt * slow
        tick += 1
        proctable.heartbeat(entry.pid, dt)
        telemetry["steps"] = tick
        telemetry["step_times"].append(dt)
        if cut:
            # control-plane partition: the payload keeps computing but
            # renewals, completions and telemetry cannot reach the pool.
            # Leases expire and the work replays elsewhere; completions
            # parked in eng.done are reported after the partition heals
            # (first completion wins keeps it exactly once either way).
            if pool.finished() and not inflight:
                break
            continue
        for rid in [r for r in inflight if r in eng.done]:
            req = inflight.pop(rid)
            # a prefill-role engine attaches the exported KV handoff; the
            # pool's on_complete hook (DisaggRouter) forwards it into the
            # decode stage.  Unified engines complete with handoff=None.
            if pool.complete(server_id, rid, req.tokens,
                             first_token_s=req.first_token_s,
                             handoff=req.handoff):
                completed_here += 1
        if inflight:
            lost = pool.renew(server_id, {rid: len(r.tokens)
                                          for rid, r in inflight.items()})
            for rid in lost:
                eng.cancel(rid)          # re-leased elsewhere: free the slot
                inflight.pop(rid, None)
        # the heartbeat consumer sees cache pressure AND per-request
        # progress — renewals piggyback on the same tick; the same sample
        # goes to the pool, where the autoscaler reads it as a demand signal
        live_sample = {
            **eng.kv_pressure(),
            "blocked_admissions": eng.blocked_admissions,
            "free_slots": eng.slots - (len(eng._live) + len(eng.queue)),
        }
        if not (site is not None and site.drop_heartbeat()):
            pool.report_telemetry(server_id, live_sample)
        telemetry["serve_live"] = {
            **live_sample,
            "inflight": {str(rid): len(r.tokens)
                         for rid, r in inflight.items()}}
        if pool.finished() and not inflight:
            break
    if inflight:                         # graceful end with work leased:
        drained = eng.drain_requests()   # give it back, don't sit on it
        pool.release(server_id, [r.rid for r in drained])
        released = len(drained)
        inflight.clear()
    pool.retire(server_id)               # gone capacity must not look live
    stats = eng._stats(decoded, time.monotonic() - t_start)
    telemetry["serve"] = {k: stats[k] for k in _SERVE_STAT_KEYS}
    telemetry["serve"]["fleet"] = {
        "server_id": server_id, "pool": pool.name, "fetched": fetched,
        "completed_here": completed_here, "released": released,
        "drained": entry.drain.is_set(),
        # leak audit on the now-idle engine: every cancel/hedge-loser/
        # revocation path must have returned its KV blocks to the pool
        "leaked_blocks": eng.block_leaks()}
    telemetry["tokens"] = {str(r.rid): r.tokens for r in eng.done.values()}
    return 0


def _train_loop(exe, key, n_steps, entry, proctable, telemetry, spec, arena,
                cap) -> int:
    """Train payload: supports checkpoint-based resume (fault tolerance)."""
    from repro.ckpt import checkpoint as ck

    state, data = exe.make_inputs(key)
    start_step = 0
    ckpt_dir = spec.get("ckpt_dir")
    ckpt_every = int(spec.get("ckpt_every", 0))
    if ckpt_dir:
        latest = ck.latest_step(ckpt_dir)
        if latest is not None:
            state = ck.restore(ckpt_dir, latest, state)
            start_step = latest
            telemetry["resumed_from"] = latest
    losses = []
    for i in range(start_step, n_steps):
        if entry.stop.is_set():
            return 143
        t0 = time.monotonic()
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = exe.fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        proctable.heartbeat(entry.pid, dt)
        telemetry["steps"] = i + 1 - start_step
        telemetry["step_times"].append(dt)
        losses.append(loss)
        if not np.isfinite(loss):
            return 3
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            ck.save(ckpt_dir, i + 1, state)
    telemetry["first_loss"] = losses[0] if losses else None
    telemetry["last_loss"] = losses[-1] if losses else None
    if ckpt_dir and losses:
        ck.save(ckpt_dir, n_steps, state)
    return 0
