"""FleetAutoscaler — demand-driven pilot provisioning with hysteresis.

The paper's late-binding model assumes the provisioning layer reacts to
demand: pilot pools on Kubernetes grow from queue pressure and shrink by
draining idle pilots (the companion work: "Auto-scaling HTCondor pools
using Kubernetes compute resources", "Demand-driven provisioning of
Kubernetes-like resources in OSG").  Every actuator already exists —
``Fleet.scale_up``/``scale_down``, ``Fleet.submit_servers``, lease
reaping, ``ExecutableRegistry.prefetch`` — this module is the closed loop
that drives them.

Signal -> policy -> actuator::

    TaskRepo.stats()            queued/leased depth, live-pilot count
    TaskRepo.scheduler_metrics  match-latency p50/p99 (observability)
    FleetDispatcher.pool_pressure
        queued/leased request backlog, pool-level TTFT p50/p99,
        kv_memory_utilization + blocked_admissions from the servers'
        per-tick telemetry heartbeats
                 |
                 v
    AutoscalePolicy: demand-proportional target with a HYSTERESIS band
        (scale up above high_water utilization, down below low_water,
        hold in between), per-direction COOLDOWNS, min/max bounds,
        down_stable_ticks (a momentary dip never sheds capacity),
        optional scale-to-zero
                 |
                 v
    scale up:   registry.prefetch(image)  — compile overlaps provisioning,
                fleet.scale_up(n)           so new pilots bind a WARM image
                fleet.submit_servers(n)   — joiners lease into the live pool
    scale down: fleet.scale_down(n)       — victims drain: a serving pilot
                releases its leased requests back (immediate requeue),
                then exits via the pilot's normal drained path

Why hysteresis + per-direction cooldowns: a pure proportional controller
flaps — a burst's tail oscillates the target across the threshold and the
fleet thrashes pilots (each flap pays a drain + a re-provision + a
re-warm).  The band makes small demand wiggles invisible; the cooldowns
bound the decision rate per direction AND forbid an opposite-direction
decision inside the new direction's cooldown of the previous one, so
"up then immediately down" cannot happen by construction (``flaps()``
counts violations; benchmarks gate it at zero).

Scale-to-zero (``min_pilots == 0``): an idle fleet sheds every pilot —
victims exit through the existing drain/idle_grace path — and the loop
re-provisions from zero on the next burst (the paper's step (g)->(h)
loop run in reverse, then forward again).

The tick is timer-wheel-paced but ACTUATES on a dedicated thread: wheel
callbacks must stay short and non-blocking (they share the lease-reaper
thread), so the periodic timer only sets an event the actuator thread
waits on.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Callable

from repro.core.timerwheel import shared_wheel


@dataclasses.dataclass
class AutoscalePolicy:
    min_pilots: int = 0                # 0 == scale-to-zero allowed
    max_pilots: int = 8
    # hysteresis band on demand / (live * slots_per_pilot): above high ->
    # grow to fit demand, below low -> shrink to fit, in between -> hold
    high_water: float = 1.25
    low_water: float = 0.5
    up_cooldown: float = 0.5           # s between scale-up decisions
    down_cooldown: float = 2.0         # s between scale-down decisions
    interval: float = 0.2              # control-loop tick period (s)
    down_stable_ticks: int = 3         # consecutive low-util ticks required
    kv_high_water: float = 0.92        # KV pressure that forces +1 in-band
    slots_per_pilot: int = 1           # per-pilot concurrent capacity


@dataclasses.dataclass
class ScaleDecision:
    t: float                           # clock time of the decision
    direction: str                     # "up" | "down"
    n: int                             # pilots added / drained
    live_before: int                   # fleet.size() at decision time
    target: int                        # post-decision effective target
    demand: int                        # backlog the decision sized against
    reason: str


class FleetAutoscaler:
    """Closed loop over one :class:`~repro.core.cluster.Fleet`.

    ``pool`` selects the SERVING mode: demand is the request backlog of a
    :class:`~repro.serving.dispatch.FleetDispatcher` and scale-ups pair new
    pilots with ``submit_servers`` so joiners lease into the live request
    pool mid-trace.  Without a pool, demand is the fleet repo's own
    queued+leased task depth (batch mode).

    ``signals_fn``/``clock`` exist for deterministic policy tests: inject
    a fake demand stream and a fake clock, drive :meth:`tick` directly.
    """

    def __init__(self, fleet, image=None, *, pool=None, pool_label=None,
                 policy: AutoscalePolicy | None = None, spec: dict | None = None,
                 signals_fn: Callable[[], dict] | None = None,
                 clock: Callable[[], float] = time.monotonic, wheel=None):
        self.fleet = fleet
        self.image = image
        self.pool = pool
        # restrict pool signals to ONE label's slice of pool_pressure()
        # ("prefill" / "decode"): two autoscalers over a disaggregated
        # fleet each size their own role's pool off its own TTFT / KV /
        # blocked-admission telemetry instead of the blended fleet view
        self.pool_label = pool_label
        self.policy = policy or AutoscalePolicy()
        self.spec = spec
        self._signals_fn = signals_fn
        self._clock = clock
        self._wheel = wheel or shared_wheel()
        self.decisions: list[ScaleDecision] = []
        self.errors: deque[str] = deque(maxlen=32)
        self.ticks = 0
        self.peak_live = 0
        self.last_signals: dict = {}
        self._last = {"up": float("-inf"), "down": float("-inf")}
        self._low_ticks = 0
        self._prev_blocked = 0
        self._prev_blocked_by_server: dict[str, int] = {}
        self._timer = None
        self._thread: threading.Thread | None = None
        self._kick = threading.Event()
        self._stop = threading.Event()

    # ---- signals -----------------------------------------------------------

    def _signals(self) -> dict:
        if self._signals_fn is not None:
            return dict(self._signals_fn())
        repo = self.fleet.sim.repo
        rs = repo.stats()
        sm = repo.scheduler_metrics()
        sig = {
            "repo_queued": rs["queued"], "repo_leased": rs["leased"],
            "repo_pilots": rs.get("pilots", 0),
            "match_p50_us": sm["match_p50_us"],
            "match_p99_us": sm["match_p99_us"],
        }
        if self.pool is not None:
            pp = self.pool.pool_pressure()
            if self.pool_label is not None:
                # overlay the label's slice: TTFT, KV pressure, blocked
                # counters, sick count and capacity stats become role-
                # split; queued/leased stay pool-wide (the queue itself
                # is not labeled — each disagg stage is its own pool)
                pp = {**pp,
                      **((pp.get("by_label") or {})
                         .get(self.pool_label) or {})}
            sig.update({f"pool_{k}": v for k, v in pp.items()})
            sig["demand"] = pp["queued"] + pp["leased"]
            sig["kv_memory_utilization"] = pp["kv_memory_utilization"]
            sig["blocked_admissions"] = pp["blocked_admissions"]
            sig["blocked_by_server"] = pp["blocked_by_server"]
        else:
            sig["demand"] = rs["queued"] + rs["leased"]
            sig.setdefault("kv_memory_utilization", 0.0)
            sig.setdefault("blocked_admissions", 0)
        return sig

    # ---- the control loop --------------------------------------------------

    def tick(self) -> ScaleDecision | None:
        """One signal->policy->actuator pass.  Returns the decision made
        (None when holding).  Thread-safe against itself only — callers
        drive it from one place (the actuator thread, or a test)."""
        p = self.policy
        now = self._clock()
        self.ticks += 1
        sig = self._signals()
        self.last_signals = sig
        live = self.fleet.size()
        # mid-drain victims still count in size(); sizing against them
        # would double-shed on back-to-back low-demand ticks; SICK servers
        # (stall-benched or quarantine-implicated, per the pool's gray-
        # failure watchdog) still hold slices but serve nothing — counting
        # them would HOLD on a demand level that needs a scale-up around
        # the sick pilot
        sick = int(sig.get("pool_sick_servers") or 0)
        effective = max(0, live - self.fleet.draining() - sick)
        self.peak_live = max(self.peak_live, live)
        cap = max(1, p.slots_per_pilot)
        # a mesh-bound (tensor-parallel) server is ONE capacity unit: its
        # slot count comes from the image's engine geometry, NOT from the
        # device count backing it.  The pool reports the live per-server
        # slot capacity (`slots_per_server`); trusting it over a stale
        # policy default keeps the demand-proportional target honest, and
        # `pool_mesh_devices` is deliberately never multiplied in — an
        # 8-device sharded server still serves `slots` requests at a time.
        srv_slots = float(sig.get("pool_slots_per_server") or 0.0)
        cap = max(cap, srv_slots)
        # speculative decoding makes capacity EFFECTIVE, not nominal: a
        # fleet whose servers commit tokens_per_step above the per-pilot
        # slot count drains the same backlog with fewer pilots.  Without
        # speculation tokens_per_step never exceeds the slot count, so the
        # max() leaves every non-speculative sizing decision unchanged.
        tps = float(sig.get("pool_tokens_per_step") or 0.0)
        cap = max(cap, tps)
        demand = int(sig.get("demand", 0))
        need = math.ceil(demand / cap) if demand > 0 else 0
        kv = float(sig.get("kv_memory_utilization") or 0.0)
        blocked_delta = self._blocked_delta(sig)

        target, reason = effective, None
        if effective == 0:
            if demand > 0:               # burst into an empty (scaled-to-
                target = need            # zero) fleet: re-provision in one
                reason = f"burst-from-zero: demand {demand}"   # jump
            self._low_ticks = 0
        else:
            util = demand / (effective * cap)
            if util > p.high_water:
                target = max(need, effective)
                reason = f"util {util:.2f} > {p.high_water} (demand {demand})"
                self._low_ticks = 0
            elif util < p.low_water:
                self._low_ticks += 1
                if self._low_ticks >= p.down_stable_ticks:
                    target = need
                    reason = (f"util {util:.2f} < {p.low_water} for "
                              f"{self._low_ticks} ticks")
            else:
                self._low_ticks = 0
                if kv > p.kv_high_water or blocked_delta > 0:
                    # queue depth looks fine but the engines are memory-
                    # bound: admissions are blocking on KV pool pressure
                    target = effective + 1
                    reason = (f"kv pressure: util {kv:.2f}, "
                              f"+{max(0, blocked_delta)} blocked")
        target = max(p.min_pilots, min(p.max_pilots, target))

        if target > effective and self._may("up", now):
            # the bound is on LIVE pilots (slices actually held), not on
            # effective: a burst while victims are mid-drain must not
            # transiently overdraw the provider's quota past max_pilots
            n = min(target - effective, p.max_pilots - live)
            if n <= 0:
                return None
            self._actuate_up(n)
            return self._record(now, "up", n, live, effective + n, demand,
                                reason or "demand")
        if target < effective and self._may("down", now):
            n = effective - target
            self.fleet.scale_down(n)
            return self._record(now, "down", n, live, target, demand,
                                reason or "idle")
        return None

    def _blocked_delta(self, sig: dict) -> int:
        """Fresh blocked admissions since the last tick.  Counters are
        cumulative PER SERVER, so the diff must be per server too: server
        churn (retire, telemetry TTL prune) shrinking or re-growing a
        fleet-wide sum must neither fabricate a scale-up trigger nor mask
        a real one.  A server first seen this tick contributes 0 (its
        history is unknown); only subsequent growth counts."""
        by_server = sig.get("blocked_by_server")
        if by_server is None:            # batch mode / injected signals:
            blocked = int(sig.get("blocked_admissions") or 0)   # plain sum
            delta = blocked - self._prev_blocked
            self._prev_blocked = blocked
            return delta
        delta = sum(max(0, int(c) - self._prev_blocked_by_server.get(s, int(c)))
                    for s, c in by_server.items())
        self._prev_blocked_by_server = {s: int(c)
                                        for s, c in by_server.items()}
        return delta

    def _may(self, direction: str, now: float) -> bool:
        """Per-direction cooldown, PLUS: a decision may not land inside its
        own cooldown of the LAST decision in either direction — that is
        what makes an up-then-down flap structurally impossible."""
        cd = (self.policy.up_cooldown if direction == "up"
              else self.policy.down_cooldown)
        return (now - self._last["up"] >= cd
                and now - self._last["down"] >= cd)

    def _record(self, now, direction, n, live, target, demand, reason):
        self._last[direction] = now
        self._low_ticks = 0
        d = ScaleDecision(now, direction, n, live, target, demand, reason)
        self.decisions.append(d)
        return d

    def _actuate_up(self, n: int):
        # prefetch FIRST: the background compile overlaps provisioning and
        # pilot boot, so the new pilots' bind joins a warm (or in-flight)
        # pull and a cold compile never lands on the request latency path
        if self.image is not None:
            try:
                self.fleet.sim.registry.prefetch(self.image, self.fleet.mesh)
            except Exception:            # noqa: BLE001 — prefetch is a hint
                pass
        started = self.fleet.scale_up(n)
        if self.pool is not None and self.image is not None:
            # pair joiners with server payloads so they lease into the live
            # request pool mid-trace (one server task per new pilot)
            self.fleet.submit_servers(self.image, self.pool.name,
                                      n=len(started), spec=self.spec)

    # ---- observability -----------------------------------------------------

    def flaps(self) -> int:
        """Consecutive opposite-direction decisions inside the newer
        decision's cooldown window.  The no-flapping acceptance gate counts
        this; the ``_may`` guard keeps it at zero by construction."""
        n = 0
        for a, b in zip(self.decisions, self.decisions[1:]):
            if a.direction != b.direction:
                cd = (self.policy.up_cooldown if b.direction == "up"
                      else self.policy.down_cooldown)
                if b.t - a.t < cd:
                    n += 1
        return n

    def stats(self) -> dict:
        ups = [d for d in self.decisions if d.direction == "up"]
        downs = [d for d in self.decisions if d.direction == "down"]
        return {
            "ticks": self.ticks,
            "decisions": len(self.decisions),
            "scale_ups": len(ups),
            "scale_downs": len(downs),
            "pilots_added": sum(d.n for d in ups),
            "pilots_drained": sum(d.n for d in downs),
            "flaps": self.flaps(),
            "peak_live": self.peak_live,
            "errors": list(self.errors),
        }

    # ---- lifecycle ---------------------------------------------------------

    def start(self):
        """Arm the periodic wheel timer and the actuator thread."""
        if self._timer is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="fleet-autoscaler")
        self._thread.start()
        # the wheel callback only kicks the event: actuation (provisioning,
        # thread spawns, repo submits) never runs on the shared wheel thread
        self._timer = self._wheel.call_periodic(
            self.policy.interval, self._kick.set, name="autoscaler-tick")

    def _loop(self):
        while True:
            self._kick.wait()
            self._kick.clear()
            if self._stop.is_set():
                return
            try:
                self.tick()
            except Exception as e:       # noqa: BLE001 — a failed tick must
                # not kill the loop; the next tick re-reads fresh signals
                self.errors.append(f"{type(e).__name__}: {e}")

    def stop(self):
        """Disarm the loop.  Does NOT touch the fleet — the owner decides
        whether to drain it."""
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._stop.set()
        self._kick.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
