"""Pilot — the pilot container's control process (paper Fig. 2, steps a-h).

One Pilot owns one provisioned slice (pod).  Its lifecycle:

  (a) start(): validate the slice, write pilot config into the private
      arena area, install the placeholder payload container;
  (b) match a task from the TaskRepo (lease) — the pilot *blocks* on the
      repo condition (`match_wait`), it never spins;
  (c) late-bind: patch the payload container's image (unprivileged, pod-
      scoped capability), stage input files + env into the shared arena,
      publish the startup spec — the payload container wakes and runs;
  (d) monitor the payload: proctable step events push telemetry, the
      lease-renew heartbeat and the monitor's wall/straggler tick run on
      the shared timer wheel, and the pilot thread itself parks on the
      executor's exit event;
  (e) collect exitcode.json + output files the instant the exit event
      fires (microseconds, not the next poll tick), report the result
      (first-completion-wins);
  (f) cleanup: executor reset (container restart) + shared-volume wipe +
      orphan sweep;
  (g) loop to (b) until drain/max_payloads/no work;
  (h) terminate: destroy the arena, release the slice.

The pilot is an explicit state machine.  States and legal transitions:

    created ──> starting ──> idle ──> bound ──> running ──> collecting
                                ^                              │
                                └──────────────────────────────┘
    idle ──> terminated            (no work / max_payloads reached)
    idle ──> drained               (graceful drain requested)
    any non-terminal ──> failed    (HardFail: injected node loss)

`bound ──> idle` and `running ──> idle` cover bind/start errors where the
payload never produces an exit record.  Terminal states: ``terminated``,
``drained``, ``failed``.  A hard-fail aborts the thread without any
cleanup — the lease-expiry path then re-queues the task elsewhere, which is
the system's node-failure story.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import uuid

from repro.core.arena import SharedArena
from repro.core import chaos
from repro.core.images import ExecutableRegistry
from repro.core.latebind import PayloadExecutor, PodPatchCapability
from repro.core.monitor import Monitor, MonitorLimits
from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable
from repro.core.taskrepo import TaskRepo, TaskResult
from repro.core.timerwheel import shared_wheel


@dataclasses.dataclass
class PilotConfig:
    max_payloads: int = 4
    idle_grace: float = 2.0            # seconds with no matching work
    monitor_interval: float = 0.05     # wall/straggler tick (timer wheel)
    lease_renew_interval: float = 1.0
    spec_timeout: float = 30.0


class HardFail(Exception):
    """Injected node failure — the pilot vanishes without cleanup."""


class InvalidTransition(Exception):
    """A state change outside the documented transition table."""


# The documented transition table (see module docstring).
TRANSITIONS: dict[str, set[str]] = {
    "created":    {"starting", "failed"},
    "starting":   {"idle", "failed"},
    "idle":       {"bound", "terminated", "drained", "failed"},
    "bound":      {"running", "idle", "failed"},
    "running":    {"collecting", "idle", "failed"},
    "collecting": {"idle", "failed"},
    "terminated": set(),
    "drained":    set(),
    "failed":     set(),
}

TERMINAL_STATES = frozenset(s for s, nxt in TRANSITIONS.items() if not nxt)


class Pilot:
    def __init__(self, slice_, repo: TaskRepo, registry: ExecutableRegistry,
                 config: PilotConfig | None = None, arena_root: str | None = None):
        self.slice = slice_
        self.repo = repo
        self.registry = registry
        self.config = config or PilotConfig()
        self.pilot_id = f"pilot-{uuid.uuid4().hex[:8]}"
        self.pod_id = f"pod-{self.pilot_id}"
        self.arena = SharedArena(arena_root)
        self.proctable = ProcessTable()
        self.executor: PayloadExecutor | None = None
        self._cap = PodPatchCapability(pod_id=self.pod_id)
        self.fail_flag = threading.Event()          # cluster failure injection
        self.drain_flag = threading.Event()         # graceful drain
        self._wake = threading.Event()              # payload exit / fail kick
        self._wheel = shared_wheel()
        self.state = "created"
        self.state_log: list[str] = ["created"]
        self.error: str | None = None    # set on soft crash (state 'failed')
        self._last_telemetry_push = 0.0
        self.payloads_run = 0
        self.history: list[dict] = []
        self._thread: threading.Thread | None = None
        # wall-clock accounting for the autoscaler's pilot-seconds metric
        self.t_started: float | None = None
        self.t_ended: float | None = None

    # ---- state machine -------------------------------------------------

    def _transition(self, to: str):
        if to not in TRANSITIONS[self.state]:
            raise InvalidTransition(f"{self.state} -> {to}")
        self.state = to
        self.state_log.append(to)

    def _force_state(self, to: str):
        """HardFail path: any non-terminal state may jump to `failed`."""
        self.state = to
        self.state_log.append(to)

    # ------------------------------------------------------------------

    def start_async(self) -> threading.Thread:
        self._thread = threading.Thread(target=self.run, daemon=True,
                                        name=self.pilot_id)
        self._thread.start()
        return self._thread

    def join(self, timeout=None):
        if self._thread:
            self._thread.join(timeout)

    def fail(self):
        """Injected hard node loss: wake the pilot wherever it is parked."""
        self.fail_flag.set()
        self._wake.set()                 # parked on a payload exit event
        self.repo.kick()                 # parked in match_wait

    def drain(self):
        """Graceful drain: stop fetching new work, and ask the CURRENT
        payload to wind down.  Batch payloads ignore the drain event and
        finish normally; a fleet-serve payload honors it by releasing its
        leased requests back to the pool (immediate requeue, no lease-TTL
        wait) and exiting — the scale-down path."""
        self.drain_flag.set()
        self.proctable.drain_uid(PAYLOAD_UID)
        self.repo.kick()                 # wake an idle pilot immediately

    def done(self) -> bool:
        """Terminal state reached AND the pilot thread has exited — the
        condition under which Fleet/ClusterSim may reap this pilot."""
        return (self.state in TERMINAL_STATES
                and (self._thread is None or not self._thread.is_alive()))

    def pilot_seconds(self, now: float | None = None) -> float:
        """Wall-clock seconds this pilot has held (or held) its slice."""
        if self.t_started is None:
            return 0.0
        end = self.t_ended
        if end is None:
            end = now if now is not None else time.monotonic()
        return max(0.0, end - self.t_started)

    def _check_fail(self):
        if self.fail_flag.is_set():
            raise HardFail(self.pilot_id)

    def _cancelled(self) -> bool:
        return self.fail_flag.is_set() or self.drain_flag.is_set()

    # ------------------------------------------------------------------

    def run(self):
        self.t_started = time.monotonic()
        try:
            self._step_a_start()
            while self.payloads_run < self.config.max_payloads:
                self._check_fail()
                if self.drain_flag.is_set():
                    break
                task = self._step_b_fetch()
                self._check_fail()
                if task is None:
                    break                # idle_grace expired / drain / no work
                self._run_payload(task)                 # steps (c)-(f)
            self._transition("drained" if self.drain_flag.is_set()
                             else "terminated")
        except HardFail:
            self._force_state("failed")                  # no cleanup at all
            return
        except Exception as e:           # noqa: BLE001
            # soft crash (bad slice, bind machinery error): reach a terminal
            # state so Fleet/live_pilots never count a dead thread, but still
            # clean up the arena and release the slice
            self.error = f"{type(e).__name__}: {e}"
            self._force_state("failed")
            self._step_h_terminate()
        finally:
            if self.state != "failed":
                self._step_h_terminate()
            self.t_ended = time.monotonic()

    # ---- (a) ----------------------------------------------------------

    def _step_a_start(self):
        self._transition("starting")
        pe = self.proctable.register(PILOT_UID, f"pilot:{self.pilot_id}")
        self._pilot_entry = pe
        # env validation: the slice must expose at least one device
        if not getattr(self.slice, "devices", None):
            raise RuntimeError("invalid slice: no devices")
        with open(f"{self.arena.private}/pilot_config.json", "w") as f:
            f.write('{"pilot_id": "%s", "pod": "%s"}' % (self.pilot_id, self.pod_id))
        self.executor = PayloadExecutor(self.pod_id, self.arena,
                                        self.proctable, self.registry,
                                        mesh=getattr(self.slice, "mesh", None))
        self.proctable.subscribe(self._on_proc_event)
        self.repo.heartbeat_pilot(self.pilot_id)
        self._transition("idle")

    def _on_proc_event(self, kind: str, entry):
        """Proctable callback: step updates push telemetry to the repo for
        fleet-median straggler detection; exits wake the parked pilot.
        Telemetry pushes are rate-limited to the monitor interval so fast
        step loops don't hammer the fleet-global repo lock from the
        payload's hot path."""
        if entry.uid != PAYLOAD_UID:
            return
        if kind == "step" and entry.last_step_time is not None:
            now = time.monotonic()
            if now - self._last_telemetry_push >= self.config.monitor_interval:
                self._last_telemetry_push = now
                self.repo.heartbeat_pilot(self.pilot_id, entry.last_step_time)
        elif kind == "exit":
            self._wake.set()

    # ---- (b) ----------------------------------------------------------

    def _pilot_ad(self) -> dict:
        return {
            "pilot_id": self.pilot_id,
            "n_devices": len(self.slice.devices),
            "labels": dict(getattr(self.slice, "labels", {})),
            "payloads_run": self.payloads_run,
        }

    def _step_b_fetch(self):
        self.repo.heartbeat_pilot(self.pilot_id)
        return self.repo.match_wait(self._pilot_ad(),
                                    timeout=self.config.idle_grace,
                                    cancel=self._cancelled)

    # ---- (c)-(f) --------------------------------------------------------

    def _run_payload(self, task):
        record = {"task_id": task.task_id, "image": task.image}
        timers = []
        monitor = Monitor(
            self.proctable,
            MonitorLimits(max_wall=task.max_wall),
            fleet_median_fn=self.repo.fleet_median_step_time)
        try:
            # (c) late bind: image patch + staging + startup spec
            exe = self.executor.patch_image(self._cap, task.image)
            for name, data in task.input_files.items():
                self.arena.stage_file(name, data)
            self._transition("bound")
            self._wake.clear()
            self.executor.start(spec_timeout=self.config.spec_timeout,
                                on_exit=self._wake.set)
            # env rides in the startup spec (the paper's startup script
            # carries the env exports): one shared-volume publish, not two;
            # payload_spec carries payload-kind extras (a serve payload's
            # request trace and engine geometry)
            self.arena.publish_startup_spec({
                "n_steps": task.n_steps,
                "task_id": task.task_id,
                "env": {**task.env, "pilot": self.pilot_id},
                **task.resume,
                **task.payload_spec,
            })
            record["bind_seconds"] = self.executor.last_bind_seconds
            record["bind_cached"] = self.executor.last_bind_cached
            self._transition("running")
            # overlap the NEXT image pull with this payload's run: the hint
            # names the image a follow-up task needs, and the registry
            # compiles it on a background thread (single-flight with any
            # concurrent bind) so the next patch_image is a cache hit
            if task.prefetch_hint is not None:
                try:
                    self.registry.prefetch(task.prefetch_hint,
                                           getattr(self.slice, "mesh", None))
                    record["prefetch_started"] = True
                except Exception:         # noqa: BLE001 — the hint is
                    pass                  # advisory; never fail the payload

            # (d) heartbeats on the shared timer wheel; the pilot thread
            # itself parks on the payload exit event (no sleep loop)
            def renew_tick():
                site = chaos.site(self.pilot_id)
                if site is not None and site.partitioned():
                    return               # control-plane cut: renewals and
                                         # heartbeats fail; the payload
                                         # keeps computing (gray failure)
                self.repo.renew(task.task_id, self.pilot_id)
                self.repo.heartbeat_pilot(self.pilot_id)

            done = self.executor.exit_event

            def monitor_tick():
                # wall/straggler enforcement still needs a clock tick, but it
                # is a timer-wheel callback, not a pilot-thread sleep loop
                monitor.scan()
                if done.is_set():
                    self._wake.set()     # belt-and-braces: never park forever

            timers.append(self._wheel.call_periodic(
                self.config.lease_renew_interval, renew_tick))
            timers.append(self._wheel.call_periodic(
                self.config.monitor_interval, monitor_tick))
            while not done.is_set() and not self.fail_flag.is_set():
                self._wake.wait()
                self._wake.clear()
            self._check_fail()
            self.executor.join(timeout=5.0)

            # (e) collect exit + outputs — fires the instant the exit event
            # is published, not at the next monitor tick
            self._transition("collecting")
            exit_info = self.arena.read_exit() or {"exitcode": 125,
                                                   "telemetry": {}}
            outputs = self.arena.output_files()
            result = TaskResult(
                task_id=task.task_id, pilot_id=self.pilot_id,
                exitcode=exit_info["exitcode"],
                telemetry=exit_info.get("telemetry", {}), outputs=outputs)
            accepted = self.repo.complete(result)
            if result.exitcode != 0:
                self.repo.release(task, failed=True)
            record["exitcode"] = result.exitcode
            record["accepted"] = accepted
            record["monitor_actions"] = [a.kind for a in monitor.actions]
        except HardFail:
            raise
        except Exception as e:                           # noqa: BLE001
            record["error"] = f"{type(e).__name__}: {e}"
            self.repo.release(task, failed=True)
        finally:
            # timers always die with the payload — a surviving renew timer
            # would keep a vanished pilot's lease alive forever
            for t in timers:
                t.cancel()
            if self.fail_flag.is_set():
                pass          # hard node loss: no cleanup at all (paper §4);
                              # the lease expires and the task re-queues
            else:
                # (f) cleanup: container restart + volume wipe + orphan sweep
                if self.executor is not None:
                    self.executor.reset(back_to_placeholder=False)
                self.arena.wipe_shared()
                self.payloads_run += 1
                self.history.append(record)
                if self.state != "idle":
                    self._transition("idle")

    # ---- (h) ----------------------------------------------------------

    def _step_h_terminate(self):
        # drop liveness/telemetry state at the repo: a terminated pilot must
        # not linger in the heartbeat map (or the straggler median) forever
        self.repo.evict_pilot(self.pilot_id)
        self.proctable.unsubscribe(self._on_proc_event)
        if self.executor is not None:
            self.executor.close()        # stop the container-runtime thread
        self.proctable.kill_uid(PAYLOAD_UID)
        pe = getattr(self, "_pilot_entry", None)
        if pe is not None:
            self.proctable.mark_exited(pe.pid, 0)
        self.arena.destroy()
        release = getattr(self.slice, "release", None)
        if release:
            release()
