"""Pilot — the pilot container's control process (paper Fig. 2, steps a-h).

One Pilot owns one provisioned slice (pod).  Its lifecycle:

  (a) start(): validate the slice, write pilot config into the private
      arena area, install the placeholder payload container;
  (b) match a task from the TaskRepo (lease);
  (c) late-bind: patch the payload container's image (unprivileged, pod-
      scoped capability), stage input files + env into the shared arena,
      publish the startup spec — the payload container wakes and runs;
  (d) monitor the payload via the shared process table; renew the lease;
      heartbeat step times to the repo (straggler telemetry);
  (e) collect exitcode.json + output files from the shared arena, report
      the result (first-completion-wins);
  (f) cleanup: executor reset (container restart) + shared-volume wipe +
      orphan sweep;
  (g) loop to (b) until drain/max_payloads/no work;
  (h) terminate: destroy the arena, release the slice.

A hard-fail flag (ClusterSim failure injection) aborts the thread without
any cleanup — the lease-expiry path then re-queues the task elsewhere,
which is the system's node-failure story.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import uuid

from repro.core.arena import SharedArena
from repro.core.images import ExecutableRegistry
from repro.core.latebind import PayloadExecutor, PodPatchCapability
from repro.core.monitor import Monitor, MonitorLimits
from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable
from repro.core.taskrepo import TaskRepo, TaskResult


@dataclasses.dataclass
class PilotConfig:
    max_payloads: int = 4
    idle_grace: float = 2.0            # seconds with no matching work
    monitor_interval: float = 0.05
    lease_renew_interval: float = 1.0
    spec_timeout: float = 30.0


class HardFail(Exception):
    """Injected node failure — the pilot vanishes without cleanup."""


class Pilot:
    def __init__(self, slice_, repo: TaskRepo, registry: ExecutableRegistry,
                 config: PilotConfig | None = None, arena_root: str | None = None):
        self.slice = slice_
        self.repo = repo
        self.registry = registry
        self.config = config or PilotConfig()
        self.pilot_id = f"pilot-{uuid.uuid4().hex[:8]}"
        self.pod_id = f"pod-{self.pilot_id}"
        self.arena = SharedArena(arena_root)
        self.proctable = ProcessTable()
        self.executor: PayloadExecutor | None = None
        self._cap = PodPatchCapability(pod_id=self.pod_id)
        self.fail_flag = threading.Event()          # cluster failure injection
        self.drain_flag = threading.Event()         # graceful drain
        self.state = "created"
        self.payloads_run = 0
        self.history: list[dict] = []
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------

    def start_async(self) -> threading.Thread:
        self._thread = threading.Thread(target=self.run, daemon=True,
                                        name=self.pilot_id)
        self._thread.start()
        return self._thread

    def join(self, timeout=None):
        if self._thread:
            self._thread.join(timeout)

    def _check_fail(self):
        if self.fail_flag.is_set():
            raise HardFail(self.pilot_id)

    # ------------------------------------------------------------------

    def run(self):
        try:
            self._step_a_start()
            idle_since = None
            while self.payloads_run < self.config.max_payloads:
                self._check_fail()
                if self.drain_flag.is_set():
                    break
                task = self._step_b_fetch()
                if task is None:
                    idle_since = idle_since or time.monotonic()
                    if time.monotonic() - idle_since > self.config.idle_grace:
                        break
                    time.sleep(0.02)
                    continue
                idle_since = None
                self._run_payload(task)                 # steps (c)-(f)
            self.state = "terminated"
        except HardFail:
            self.state = "failed"                        # no cleanup at all
            return
        finally:
            if self.state != "failed":
                self._step_h_terminate()

    # ---- (a) ----------------------------------------------------------

    def _step_a_start(self):
        self.state = "starting"
        pe = self.proctable.register(PILOT_UID, f"pilot:{self.pilot_id}")
        self._pilot_entry = pe
        # env validation: the slice must expose at least one device
        if not getattr(self.slice, "devices", None):
            raise RuntimeError("invalid slice: no devices")
        with open(f"{self.arena.private}/pilot_config.json", "w") as f:
            f.write('{"pilot_id": "%s", "pod": "%s"}' % (self.pilot_id, self.pod_id))
        self.executor = PayloadExecutor(self.pod_id, self.arena,
                                        self.proctable, self.registry,
                                        mesh=getattr(self.slice, "mesh", None))
        self.repo.heartbeat_pilot(self.pilot_id)
        self.state = "idle"

    # ---- (b) ----------------------------------------------------------

    def _pilot_ad(self) -> dict:
        return {
            "pilot_id": self.pilot_id,
            "n_devices": len(self.slice.devices),
            "labels": dict(getattr(self.slice, "labels", {})),
            "payloads_run": self.payloads_run,
        }

    def _step_b_fetch(self):
        self.repo.heartbeat_pilot(self.pilot_id)
        return self.repo.match(self._pilot_ad())

    # ---- (c)-(f) --------------------------------------------------------

    def _run_payload(self, task):
        self.state = f"payload:{task.task_id}"
        record = {"task_id": task.task_id, "image": task.image}
        t_bind0 = time.monotonic()
        try:
            # (c) late bind: image patch + staging + startup spec
            exe = self.executor.patch_image(self._cap, task.image)
            for name, data in task.input_files.items():
                self.arena.stage_file(name, data)
            self.arena.write_env({**task.env, "pilot": self.pilot_id})
            self.executor.start(spec_timeout=self.config.spec_timeout)
            self.arena.publish_startup_spec({
                "n_steps": task.n_steps,
                "task_id": task.task_id,
                **task.resume,
            })
            record["bind_seconds"] = self.executor.last_bind_seconds
            record["bind_cached"] = self.executor.last_bind_cached

            # (d) monitor until exit
            monitor = Monitor(
                self.proctable,
                MonitorLimits(max_wall=task.max_wall),
                fleet_median_fn=self.repo.fleet_median_step_time)
            last_renew = 0.0
            while self.executor.running:
                self._check_fail()
                monitor.scan()
                now = time.monotonic()
                if now - last_renew > self.config.lease_renew_interval:
                    self.repo.renew(task.task_id, self.pilot_id)
                    last_renew = now
                # publish step telemetry for fleet-median straggler detection
                for e in self.proctable.entries(uid=PAYLOAD_UID):
                    if e.last_step_time is not None:
                        self.repo.heartbeat_pilot(self.pilot_id, e.last_step_time)
                time.sleep(self.config.monitor_interval)
            self.executor.join(timeout=5.0)

            # (e) collect exit + outputs
            exit_info = self.arena.read_exit() or {"exitcode": 125,
                                                   "telemetry": {}}
            outputs = {}
            for rel in self.arena.shared_files():
                if rel.startswith("out/"):
                    with open(f"{self.arena.shared}/{rel}", "rb") as f:
                        outputs[rel] = f.read()
            result = TaskResult(
                task_id=task.task_id, pilot_id=self.pilot_id,
                exitcode=exit_info["exitcode"],
                telemetry=exit_info.get("telemetry", {}), outputs=outputs)
            accepted = self.repo.complete(result)
            if result.exitcode != 0:
                self.repo.release(task, failed=True)
            record["exitcode"] = result.exitcode
            record["accepted"] = accepted
            record["monitor_actions"] = [a.kind for a in monitor.actions]
        except HardFail:
            raise
        except Exception as e:                           # noqa: BLE001
            record["error"] = f"{type(e).__name__}: {e}"
            self.repo.release(task, failed=True)
        finally:
            # (f) cleanup: container restart + volume wipe + orphan sweep
            if self.executor is not None:
                self.executor.reset(back_to_placeholder=False)
            self.arena.wipe_shared()
            self.payloads_run += 1
            self.history.append(record)
            self.state = "idle"

    # ---- (h) ----------------------------------------------------------

    def _step_h_terminate(self):
        self.proctable.kill_uid(PAYLOAD_UID)
        pe = getattr(self, "_pilot_entry", None)
        if pe is not None:
            self.proctable.mark_exited(pe.pid, 0)
        self.arena.destroy()
        release = getattr(self.slice, "release", None)
        if release:
            release()
