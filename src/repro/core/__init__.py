"""The paper's contribution: unprivileged container late-binding for dHTC
pilots, adapted to a JAX/TPU fleet.

Map (paper -> here): pod -> PilotSlice; pilot container -> Pilot; payload
container -> PayloadExecutor; container image -> PayloadImage (compiled XLA
executable); pod patch -> PayloadExecutor.patch_image (pod-scoped
capability); shared volume -> SharedArena; process namespace + uid ->
ProcessTable; startup wrapper -> run_wrapper; task repository -> TaskRepo;
Kubernetes -> ClusterSim.
"""

from repro.core.arena import SharedArena
from repro.core.cluster import ClusterSim, Fleet, PilotSlice
from repro.core.images import (
    Executable, ExecutableRegistry, PLACEHOLDER, PayloadImage,
)
from repro.core.latebind import (
    PayloadExecutor, PermissionError_, PodPatchCapability,
)
from repro.core.monitor import Monitor, MonitorAction, MonitorLimits
from repro.core.pilot import (
    InvalidTransition, Pilot, PilotConfig, TERMINAL_STATES, TRANSITIONS,
)
from repro.core.proctable import PAYLOAD_UID, PILOT_UID, ProcessTable
from repro.core.taskrepo import PayloadTask, TaskRepo, TaskResult
from repro.core.timerwheel import TimerWheel, shared_wheel
from repro.core.wrapper import PayloadCapability, run_wrapper

__all__ = [
    "SharedArena", "ClusterSim", "Fleet", "PilotSlice", "Executable",
    "ExecutableRegistry", "PLACEHOLDER", "PayloadImage", "PayloadExecutor",
    "PermissionError_", "PodPatchCapability", "Monitor", "MonitorAction",
    "MonitorLimits", "InvalidTransition", "Pilot", "PilotConfig",
    "TERMINAL_STATES", "TRANSITIONS", "PAYLOAD_UID", "PILOT_UID",
    "ProcessTable", "PayloadTask", "TaskRepo", "TaskResult", "TimerWheel",
    "shared_wheel", "PayloadCapability", "run_wrapper",
]
