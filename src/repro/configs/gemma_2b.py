"""gemma-2b — dense, GeGLU, head_dim=256, MQA (kv=1).  [arXiv:2403.08295; hf]

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000, tied embeddings.
"""

from repro.configs.base import ArchConfig, register, register_smoke

NAME = "gemma-2b"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp_gated=True,
        activation="gelu",      # GeGLU
        tie_embeddings=True,
        norm="rmsnorm",
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        activation="gelu",
        tie_embeddings=True,
        attn_chunk=64,
    )
