"""mamba2-370m — pure SSM (state-space duality / SSD).  [arXiv:2405.21060]

48L d_model=1024, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*1024 = 2048, head_dim 64 -> 32 SSD heads.  O(1) decode state.
"""

from repro.configs.base import ArchConfig, SSMSpec, register, register_smoke

NAME = "mamba2-370m"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,            # attention-free
        num_kv_heads=0,
        d_ff=0,                 # mamba2 blocks have no separate FFN
        vocab_size=50280,
        ssm=SSMSpec(state_dim=128, head_dim=64, expand=2, conv_width=4,
                    chunk_size=256),
        attn_period=10**9,      # no attention layers at all
        norm="rmsnorm",
        tie_embeddings=True,
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        ssm=SSMSpec(state_dim=16, head_dim=16, expand=2, conv_width=4,
                    chunk_size=32),
        attn_period=10**9,
        tie_embeddings=True,
    )
