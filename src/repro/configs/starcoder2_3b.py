"""starcoder2-3b — dense, GQA kv=2, RoPE, plain-GELU MLP, LayerNorm.

[arXiv:2402.19173; hf]  30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152.
"""

from repro.configs.base import ArchConfig, register, register_smoke

NAME = "starcoder2-3b"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        mlp_gated=False,        # classic c_fc -> gelu -> c_proj
        activation="gelu",
        norm="layernorm",
        rope_theta=999_999.0,   # starcoder2 uses a large rope base
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        mlp_gated=False,
        activation="gelu",
        norm="layernorm",
        attn_chunk=64,
    )
