"""smollm-360m — llama-arch small dense model.  [hf:HuggingFaceTB/SmolLM; hf]

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, SwiGLU, RMSNorm, tied.
"""

from repro.configs.base import ArchConfig, register, register_smoke

NAME = "smollm-360m"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        mlp_gated=True,
        activation="silu",
        tie_embeddings=True,
        norm="rmsnorm",
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        num_layers=2,
        d_model=60,             # keeps the odd 15-head flavour: 4 heads x 15
        num_heads=3,
        num_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        tie_embeddings=True,
        attn_chunk=64,
    )
