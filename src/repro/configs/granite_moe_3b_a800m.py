"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512-per-expert vocab=49155, MoE every layer.
"""

from repro.configs.base import ArchConfig, MoESpec, register, register_smoke

NAME = "granite-moe-3b-a800m"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=0,                 # all-MoE FFN
        vocab_size=49155,
        mlp_gated=True,
        activation="silu",
        moe=MoESpec(num_experts=40, top_k=8, d_ff_expert=512),
        moe_period=1,
        norm="rmsnorm",
        tie_embeddings=True,
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        moe=MoESpec(num_experts=8, top_k=4, d_ff_expert=32),
        moe_period=1,
        tie_embeddings=True,
        attn_chunk=64,
    )
