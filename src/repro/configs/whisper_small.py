"""whisper-small — encoder-decoder with conv audio frontend (STUB).

[arXiv:2212.04356; unverified]  12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865, LayerNorm, plain-GELU MLP.  Per the assignment the conv frontend
is a STUB: ``input_specs()`` provides precomputed frame embeddings
(1500 frames = 30 s of audio after the 2x conv downsampling).
Full attention -> long_500k skipped.  Decode shapes lower the DECODER step
(self-attn KV cache at seq_len + fixed cross-attn to the encoder output).
"""

from repro.configs.base import ArchConfig, register, register_smoke

NAME = "whisper-small"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="audio",
        num_layers=12,          # decoder layers
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        mlp_gated=False,
        activation="gelu",
        norm="layernorm",
        encoder_layers=12,
        frontend_tokens=1500,   # precomputed mel->conv frame embeddings (stub)
        tie_embeddings=True,
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        mlp_gated=False,
        activation="gelu",
        norm="layernorm",
        encoder_layers=2,
        frontend_tokens=32,
        tie_embeddings=True,
        attn_chunk=64,
    )
