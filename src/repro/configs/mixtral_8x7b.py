"""mixtral-8x7b — MoE 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA window 4096 -> sub-quadratic decode; long_500k runs with a rolling KV cache.
"""

from repro.configs.base import ArchConfig, MoESpec, register, register_smoke

NAME = "mixtral-8x7b"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=32000,
        sliding_window=4096,
        mlp_gated=True,
        activation="silu",
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=14336),
        moe_period=1,
        norm="rmsnorm",
        rope_theta=1_000_000.0,
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        sliding_window=64,
        moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=128),
        moe_period=1,
        attn_chunk=64,
    )
