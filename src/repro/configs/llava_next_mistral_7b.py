"""llava-next-mistral-7b — VLM: mistral-7b backbone + anyres patch frontend.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, SwiGLU.
Per the assignment the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (anyres base tile = 576 patches of CLIP-ViT-L/14
@336px); the backbone prepends them to the token embeddings.
Full attention (llava-1.6 disables mistral's sliding window) -> long_500k skipped.
"""

from repro.configs.base import ArchConfig, register, register_smoke

NAME = "llava-next-mistral-7b"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        mlp_gated=True,
        activation="silu",
        norm="rmsnorm",
        frontend_tokens=576,    # one base anyres tile, precomputed (stub)
        rope_theta=1_000_000.0,
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        frontend_tokens=16,
        attn_chunk=64,
    )
