"""Architecture + input-shape configuration system.

Every assigned architecture is described by an :class:`ArchConfig`; every
assigned input shape by a :class:`ShapeSpec`.  A ``(ArchConfig, ShapeSpec)``
pair is exactly what the paper calls a *payload*: the pilot system late-binds
it onto an already-provisioned slice (see ``repro.core.images.PayloadImage``).

Nothing in this module touches jax device state; configs are plain frozen
dataclasses so they can be hashed into compile-cache keys.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

# --------------------------------------------------------------------------
# Sub-specs for the model families that need extra structure
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts FFN block."""

    num_experts: int
    top_k: int
    d_ff_expert: int          # hidden width of ONE expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # "tp": expert hidden dim sharded over the model axis (tokens stay put).
    # "ep": experts sharded over the model axis (tokens all-to-all).
    partition: str = "tp"


@dataclasses.dataclass(frozen=True)
class MLASpec:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 SSD mixer."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1          # B/C groups shared across heads


# --------------------------------------------------------------------------
# The architecture config
# --------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int                    # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int                         # dense FFN hidden width (0 if all-MoE)
    vocab_size: int

    head_dim: int = 0                 # 0 -> d_model // num_heads
    # ---- attention flavour ----
    sliding_window: int | None = None   # SWA width (mixtral)
    rope_theta: float = 10_000.0
    mla: MLASpec | None = None
    # ---- FFN flavour ----
    mlp_gated: bool = True            # SwiGLU/GeGLU vs plain MLP
    activation: str = "silu"          # silu | gelu
    moe: MoESpec | None = None
    moe_period: int = 1               # MoE FFN every `period` layers (jamba: 2)
    # ---- SSM / hybrid ----
    ssm: SSMSpec | None = None
    attn_period: int = 1              # hybrid: 1 attention layer per period
                                      # (jamba: 8 -> 7 mamba + 1 attn)
    # ---- encoder-decoder / frontend stubs ----
    encoder_layers: int = 0           # whisper: 12 encoder layers
    frontend_tokens: int = 0          # stub tokens (llava patches / whisper frames)
    # ---- misc ----
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    # remat policy for the layer scan: "full" | "dots" | "none"
    remat: str = "full"
    # attention implementation: "chunked" (pure-JAX flash-style, default),
    # "causal_blocked" (static triangular block skipping — beyond-paper opt),
    # "pallas" (TPU kernel path)
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    # sequence-chunked fused CE loss (logits never fully materialized)
    loss_chunk: int = 1024
    # SSM mixer implementation: "chunked" (pure-JAX SSD) | "pallas"
    ssm_impl: str = "chunked"
    # MoE expert matmul: "einsum" (capacity buckets) | "gmm" (Pallas kernel)
    moe_impl: str = "einsum"
    # norm implementation: "jnp" | "pallas" (fused kernel)
    norm_impl: str = "jnp"

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities -------------------------------------------------

    @property
    def is_attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is feasible (assignment: run long_500k
        only for SSM / hybrid / sliding-window archs)."""
        if self.ssm is not None:
            return True
        return self.sliding_window is not None

    def attn_layer_indices(self) -> tuple[int, ...]:
        """Decoder layers that are attention (hybrid archs interleave)."""
        if self.is_attention_free:
            return ()
        if self.ssm is None:
            return tuple(range(self.num_layers))
        # hybrid: 1 attention layer per attn_period, at the end of each period
        # (jamba: layer 7, 15, 23, 31 in a 1:7 interleave)
        return tuple(
            i for i in range(self.num_layers)
            if (i % self.attn_period) == self.attn_period - 1
        )

    def moe_layer_indices(self) -> tuple[int, ...]:
        if self.moe is None:
            return ()
        return tuple(
            i for i in range(self.num_layers) if (i % self.moe_period) == self.moe_period - 1
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory checks)."""
        D, V = self.d_model, self.vocab_size
        total = V * D                      # embedding
        if not self.tie_embeddings:
            total += V * D                 # lm head
        attn_set = set(self.attn_layer_indices())
        moe_set = set(self.moe_layer_indices())
        for i in range(self.num_layers):
            total += self._mixer_params(i in attn_set)
            total += self._ffn_params(i in moe_set)
            total += 2 * D                 # two norms per layer
        total += D                         # final norm
        if self.is_encdec:
            # encoder layers: self-attn + ffn; decoder adds cross-attn
            enc = self.encoder_layers * (
                self._attn_params() + self._dense_ffn_params() + 2 * D
            )
            dec_cross = self.num_layers * (self._attn_params() + D)
            total += enc + dec_cross + D
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff_expert if self.mlp_gated else 2 * self.d_model * m.d_ff_expert
        inactive = (m.num_experts - m.top_k) * per_expert * len(self.moe_layer_indices())
        return self.param_count() - inactive

    # -- helpers --

    def _attn_params(self) -> int:
        D = self.d_model
        if self.mla is not None:
            s = self.mla
            H = self.num_heads
            return (
                D * s.q_lora_rank
                + s.q_lora_rank * H * s.qk_head_dim
                + D * (s.kv_lora_rank + s.qk_rope_head_dim)
                + s.kv_lora_rank * H * (s.qk_nope_head_dim + s.v_head_dim)
                + H * s.v_head_dim * D
            )
        Dh = self.head_dim
        return D * self.num_heads * Dh + 2 * D * self.num_kv_heads * Dh + self.num_heads * Dh * D

    def _ssm_params(self) -> int:
        s = self.ssm
        D = self.d_model
        d_inner = s.expand * D
        nheads = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.n_groups * s.state_dim
        return (
            D * (2 * d_inner + 2 * s.n_groups * s.state_dim + nheads)  # in_proj
            + conv_dim * s.conv_width                                   # conv1d
            + nheads * 2                                                # A_log, D
            + nheads                                                    # dt_bias
            + d_inner                                                   # gated norm
            + d_inner * D                                               # out_proj
        )

    def _mixer_params(self, is_attn: bool) -> int:
        return self._attn_params() if is_attn else self._ssm_params()

    def _dense_ffn_params(self) -> int:
        mult = 3 if self.mlp_gated else 2
        return mult * self.d_model * self.d_ff

    def _ffn_params(self, is_moe: bool) -> int:
        if not is_moe:
            return self._dense_ffn_params()
        m = self.moe
        mult = 3 if self.mlp_gated else 2
        return self.d_model * m.num_experts + m.num_experts * mult * self.d_model * m.d_ff_expert


# --------------------------------------------------------------------------
# Input shapes (assigned: 4 per LM arch)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Mapping[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> tuple[str, ...]:
    """Which assigned shapes run for this arch (skips recorded in DESIGN.md)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return tuple(out)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}
_SMOKE_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def register_smoke(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _SMOKE_REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _SMOKE_REGISTRY:
        raise KeyError(f"no smoke config for {name!r}")
    return _SMOKE_REGISTRY[name]()


def list_archs() -> tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        jamba_v01_52b, gemma_2b, starcoder2_3b, smollm_360m, minicpm3_4b,
        llava_next_mistral_7b, granite_moe_3b_a800m, mixtral_8x7b,
        mamba2_370m, whisper_small,
    )
