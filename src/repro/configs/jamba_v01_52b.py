"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Real Jamba: attention every 8th layer, MoE every other layer, 16 experts top-2.
Jamba uses Mamba-1 mixers; we implement the Mamba-2 SSD formulation instead —
the SSD dual form is the MXU-friendly TPU adaptation of the same selective-SSM
recurrence (documented in DESIGN.md §2: hardware-adaptation notes).
"""

from repro.configs.base import ArchConfig, MoESpec, SSMSpec, register, register_smoke

NAME = "jamba-v0.1-52b"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        mlp_gated=True,
        activation="silu",
        moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=14336),
        moe_period=2,
        ssm=SSMSpec(state_dim=16, head_dim=64, expand=2, conv_width=4, chunk_size=256),
        attn_period=8,
        norm="rmsnorm",
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="hybrid",
        num_layers=8,           # one full period: 7 mamba + 1 attn, 4 MoE
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=128),
        moe_period=2,
        ssm=SSMSpec(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk_size=32),
        attn_period=8,
        attn_chunk=64,
    )
