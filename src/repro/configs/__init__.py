"""Architecture configs — one module per assigned architecture.

``get_config(name)`` returns the full published config (dry-run only);
``get_smoke_config(name)`` returns a reduced same-family config that runs a
real forward/train step on CPU in the test suite.
"""

from repro.configs.base import (
    ArchConfig,
    MLASpec,
    MoESpec,
    SHAPES,
    SSMSpec,
    ShapeSpec,
    applicable_shapes,
    get_config,
    get_smoke_config,
    list_archs,
)

__all__ = [
    "ArchConfig", "MLASpec", "MoESpec", "SSMSpec", "ShapeSpec", "SHAPES",
    "applicable_shapes", "get_config", "get_smoke_config", "list_archs",
]
