"""minicpm3-4b — dense with MLA (multi-head latent attention).

[hf:openbmb/MiniCPM3-4B; hf]  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA dims from the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
Decode caches the compressed latent (kv_lora + k_rope) — the MLA win.
"""

from repro.configs.base import ArchConfig, MLASpec, register, register_smoke

NAME = "minicpm3-4b"


@register(NAME)
def config() -> ArchConfig:
    return ArchConfig(
        name=NAME,
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        mla=MLASpec(
            q_lora_rank=768,
            kv_lora_rank=256,
            qk_nope_head_dim=64,
            qk_rope_head_dim=32,
            v_head_dim=64,
        ),
        mlp_gated=True,
        activation="silu",
        norm="rmsnorm",
    )


@register_smoke(NAME)
def smoke() -> ArchConfig:
    return ArchConfig(
        name=NAME + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        mla=MLASpec(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        attn_chunk=64,
    )
