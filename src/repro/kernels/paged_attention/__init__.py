from repro.kernels.paged_attention.ops import paged_decode_attention  # noqa: F401
