"""jit'd public wrapper for the paged flash-decode Pallas kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention_kernel, paged_verify_attention_kernel,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, cache_len, *,
                           interpret=None):
    """q: (B,H,Dh) one new token per sequence; pools: (nb, bs, K, Dh) shared
    block pool; block_tables: (B, mb) int32; cache_len: scalar or (B,) valid
    count.  Returns (B,H,Dh).

    The logical sequence of row ``b`` is ``pool[table[b, p // bs], p % bs]``
    for ``p < cache_len[b]``; table entries past the row's allocation point
    at the reserved scratch block (id 0) and are masked out by the ragged
    lengths, so they are never read into the softmax."""
    B, H, Dh = q.shape
    K = k_pool.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()
    qg = q.reshape(B, K, G, Dh)
    o = paged_decode_attention_kernel(qg, k_pool, v_pool, block_tables,
                                      cache_len, interpret=interpret)
    return o.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(q, k_pool, v_pool, block_tables, q_off, *,
                           interpret=None):
    """k-query flash-decode for speculative verify.  q: (B,S,H,Dh) — the
    S = k+1 verify queries of each row, query ``s`` at absolute position
    ``q_off[b] + s``; pools: (nb, bs, K, Dh); block_tables: (B, mb) int32;
    q_off: scalar or (B,) base positions.  Returns (B,S,H,Dh).

    One walk of the row's block table serves all S queries (a staircase
    causal mask instead of S ragged lengths), so the verify step streams
    each KV block from HBM once, not S times."""
    B, S, H, Dh = q.shape
    K = k_pool.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()
    qg = q.reshape(B, S, K, G, Dh)
    o = paged_verify_attention_kernel(qg, k_pool, v_pool, block_tables,
                                      q_off, interpret=interpret)
    return o.reshape(B, S, H, Dh)
