"""jit'd public wrapper for the paged flash-decode Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                    # moved to jax.shard_map in new jax
    from jax.experimental.shard_map import shard_map
except ImportError:                     # pragma: no cover
    from jax import shard_map

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention_kernel, paged_verify_attention_kernel,
)
from repro.runtime.mesh import MODEL_AXIS, mesh_axis_size


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, cache_len, *,
                           interpret=None):
    """q: (B,H,Dh) one new token per sequence; pools: (nb, bs, K, Dh) shared
    block pool; block_tables: (B, mb) int32; cache_len: scalar or (B,) valid
    count.  Returns (B,H,Dh).

    The logical sequence of row ``b`` is ``pool[table[b, p // bs], p % bs]``
    for ``p < cache_len[b]``; table entries past the row's allocation point
    at the reserved scratch block (id 0) and are masked out by the ragged
    lengths, so they are never read into the softmax."""
    B, H, Dh = q.shape
    K = k_pool.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()
    qg = q.reshape(B, K, G, Dh)
    o = paged_decode_attention_kernel(qg, k_pool, v_pool, block_tables,
                                      cache_len, interpret=interpret)
    return o.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(q, k_pool, v_pool, block_tables, q_off, *,
                           interpret=None):
    """k-query flash-decode for speculative verify.  q: (B,S,H,Dh) — the
    S = k+1 verify queries of each row, query ``s`` at absolute position
    ``q_off[b] + s``; pools: (nb, bs, K, Dh); block_tables: (B, mb) int32;
    q_off: scalar or (B,) base positions.  Returns (B,S,H,Dh).

    One walk of the row's block table serves all S queries (a staircase
    causal mask instead of S ragged lengths), so the verify step streams
    each KV block from HBM once, not S times."""
    B, S, H, Dh = q.shape
    K = k_pool.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()
    qg = q.reshape(B, S, K, G, Dh)
    o = paged_verify_attention_kernel(qg, k_pool, v_pool, block_tables,
                                      q_off, interpret=interpret)
    return o.reshape(B, S, H, Dh)


# --------------------------------------------------------------------------
# tensor-parallel (head-sharded) wrappers
# --------------------------------------------------------------------------
# Each mesh shard runs the SAME Pallas kernel on its local contiguous head
# slice: q on dim 1 (decode) / dim 2 (verify) over "model", pools on their
# K dim (2), block tables + lengths replicated (they are the scalar-prefetch
# operands — every shard walks the same table).  The contiguous-heads split
# aligns with the kv-group mapping (query head h attends kv head h // G), so
# shard s owns query heads [s*H/m, (s+1)*H/m) and exactly the kv heads
# [s*K/m, (s+1)*K/m) they attend — no cross-shard communication, and every
# per-head softmax is bitwise identical to the single-device kernel.
# check_rep=False: pallas_call inside shard_map cannot prove replication.

def tp_heads(mesh, num_kv_heads: int, num_heads: int) -> bool:
    """True iff the kernel can be head-sharded on this mesh: the model axis
    must divide the KV head count (whole kv-groups per shard)."""
    if mesh is None:
        return False
    m = mesh_axis_size(mesh, MODEL_AXIS)
    return m > 1 and num_kv_heads % m == 0 and num_heads % m == 0


def _len_spec(x) -> P:
    return P() if jnp.ndim(x) == 0 else P(*([None] * jnp.ndim(x)))


def paged_decode_attention_tp(q, k_pool, v_pool, block_tables, cache_len,
                              mesh, *, interpret=None):
    """Head-sharded paged_decode_attention under shard_map.  Same contract;
    q (B,H,Dh) sharded on H, pools (nb,bs,K,Dh) sharded on K, output
    (B,H,Dh) sharded on H.  Requires :func:`tp_heads`."""
    if interpret is None:
        interpret = not _on_tpu()
    fn = shard_map(
        functools.partial(paged_decode_attention, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, MODEL_AXIS, None), P(None, None, MODEL_AXIS, None),
                  P(None, None, MODEL_AXIS, None), P(None, None),
                  _len_spec(cache_len)),
        out_specs=P(None, MODEL_AXIS, None),
        check_rep=False)
    return fn(q, k_pool, v_pool, block_tables, cache_len)


def paged_verify_attention_tp(q, k_pool, v_pool, block_tables, q_off,
                              mesh, *, interpret=None):
    """Head-sharded paged_verify_attention under shard_map.  q (B,S,H,Dh)
    sharded on H; pools on K; output sharded on H."""
    if interpret is None:
        interpret = not _on_tpu()
    fn = shard_map(
        functools.partial(paged_verify_attention, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, None, MODEL_AXIS, None),
                  P(None, None, MODEL_AXIS, None),
                  P(None, None, MODEL_AXIS, None), P(None, None),
                  _len_spec(q_off)),
        out_specs=P(None, None, MODEL_AXIS, None),
        check_rep=False)
    return fn(q, k_pool, v_pool, block_tables, q_off)
