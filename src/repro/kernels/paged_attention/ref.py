"""Pure-jnp oracle for paged single-token decode attention.

The KV cache is a shared block pool ``(num_blocks, block_size, K, Dh)``;
each batch row owns a *block table* ``(max_blocks,)`` of physical block
ids mapping logical position ``p`` to ``pool[table[p // bs], p % bs]``.
The oracle gathers each row's logical view and defers to the dense
decode-attention oracle, so kernel-vs-ref equality also certifies the
gather semantics.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def gather_kv(pool, block_tables):
    """pool: (nb, bs, ...); block_tables: (B, mb) int32.
    Returns the per-row logical view (B, mb*bs, ...)."""
    B, mb = block_tables.shape
    g = pool[block_tables]                     # (B, mb, bs, ...)
    return g.reshape((B, mb * pool.shape[1]) + pool.shape[2:])


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, cache_len):
    """q: (B,H,Dh); pools: (nb, bs, K, Dh); block_tables: (B, mb) int32;
    cache_len: scalar or (B,) valid-entry count.  Returns (B,H,Dh)."""
    kg = gather_kv(k_pool, block_tables)
    vg = gather_kv(v_pool, block_tables)
    return decode_attention_ref(q, kg, vg, cache_len)


def paged_verify_attention_ref(q, k_pool, v_pool, block_tables, q_off):
    """k-query speculative-verify oracle.  q: (B,S,H,Dh) — query ``s`` of
    row ``b`` sits at absolute position ``q_off[b] + s`` and attends the
    causal prefix ``t <= q_off[b] + s``; the per-query loop defers to the
    single-token oracle so each query's math (shapes, masks, reduction
    order) is EXACTLY one plain decode step's.  Returns (B,S,H,Dh)."""
    kg = gather_kv(k_pool, block_tables)
    vg = gather_kv(v_pool, block_tables)
    T = kg.shape[1]
    outs = [decode_attention_ref(q[:, s], kg, vg,
                                 jnp.minimum(q_off + s + 1, T))
            for s in range(q.shape[1])]
    return jnp.stack(outs, axis=1)
