"""Paged flash-decode: single-token attention over a block-pool KV cache.

The pool ``(num_blocks, block_size, K, Dh)`` is shared by every sequence;
a per-row block table maps logical position ``p`` of batch row ``b`` to
``pool[table[b, p // bs], p % bs]``.  Grid = (B, K, mb): the last axis
walks the row's block table sequentially, carrying the online-softmax
state in VMEM scratch.  Both the ragged lengths AND the block tables
arrive via scalar prefetch (SMEM), so the physical block to stream into
VMEM is chosen by the BlockSpec index_map — the gather never materializes
a contiguous copy of the sequence, which is the whole point of paging:
HBM holds exactly the live blocks, and admission-time block remapping
(prefix reuse) costs zero copies.

Blocks past ``cache_len`` skip their compute entirely (their table
entries point at the reserved scratch block), so short sequences pay for
the blocks they own, not for the table width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(len_ref, btab_ref, q_ref, k_ref, v_ref, o_ref,
                  m_sc, l_sc, acc_sc, *, scale, block_size, n_b):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    b = pl.program_id(0)
    t_pos = ti * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = (t_pos < len_ref[b])[0]                       # (block_size,)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]                                   # (G, Dh)
        k = k_ref[0, :, 0]                                # (block_size, Dh)
        # zero invalid rows so 0-weight garbage can't poison p@v
        v = jnp.where(valid[:, None], v_ref[0, :, 0], 0.0)
        s = jax.lax.dot_general(
            q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, block_size)
        s = jnp.where(valid[None], s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, Dh)
        acc_sc[...] = acc_sc[...] * alpha[..., None] + pv
        m_sc[...] = m_new

    @pl.when(ti == n_b - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[..., None]).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, block_tables, cache_len,
                                  *, interpret=False):
    """q: (B,K,G,Dh); pools: (nb, block_size, K, Dh); block_tables: (B, mb)
    int32 physical block ids; cache_len: (B,) int32 valid positions."""
    B, K, G, Dh = q.shape
    nb, block_size = k_pool.shape[0], k_pool.shape[1]
    mb = block_tables.shape[1]
    scale = 1.0 / (Dh ** 0.5)

    kernel = functools.partial(_paged_kernel, scale=scale,
                               block_size=block_size, n_b=mb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # lens, block_tables
        grid=(B, K, mb),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, h, ti, lens, btab: (b, h, 0, 0)),
            # the paged gather: the physical block streamed into VMEM is
            # picked from the prefetched table, per grid cell
            pl.BlockSpec((1, block_size, 1, Dh),
                         lambda b, h, ti, lens, btab: (btab[b, ti], 0, h, 0)),
            pl.BlockSpec((1, block_size, 1, Dh),
                         lambda b, h, ti, lens, btab: (btab[b, ti], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, ti, lens, btab: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lens, block_tables.astype(jnp.int32), q, k_pool, v_pool)


def _paged_verify_kernel(off_ref, btab_ref, q_ref, k_ref, v_ref, o_ref,
                         m_sc, l_sc, acc_sc, *, scale, block_size, n_b,
                         n_q, group):
    """k-query variant: ``n_q`` speculative queries per row share one walk
    of the block table.  Query ``s`` sits at absolute position
    ``off[b] + s`` and its causal reach is ``t <= off[b] + s`` — a
    staircase mask instead of the decode kernel's single ragged length."""
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    b = pl.program_id(0)
    t_pos = ti * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    # the deepest query reaches t <= off + n_q - 1; blocks wholly past
    # that skip their compute entirely
    valid_any = (t_pos <= off_ref[b] + n_q - 1)[0]        # (block_size,)

    @pl.when(jnp.any(valid_any))
    def _compute():
        q = q_ref[0, :, 0]                                # (n_q, G, Dh)
        q = q.reshape(n_q * group, q.shape[-1])
        k = k_ref[0, :, 0]                                # (block_size, Dh)
        v = jnp.where(valid_any[:, None], v_ref[0, :, 0], 0.0)
        s = jax.lax.dot_general(
            q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (n_q*G, bs)
        # staircase causal mask: row r = s*G + g covers t <= off + s
        s_idx = jax.lax.broadcasted_iota(
            jnp.int32, (n_q * group, block_size), 0) // group
        tcol = ti * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (n_q * group, block_size), 1)
        valid = tcol <= off_ref[b] + s_idx
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # a row can be ENTIRELY masked in this block (shallow query, deep
        # block): then m_new == NEG_INF and exp(s - m_new) == 1, not 0 —
        # zero masked entries explicitly so they never enter l / acc
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (n_q*G, Dh)
        acc_sc[...] = acc_sc[...] * alpha[..., None] + pv
        m_sc[...] = m_new

    @pl.when(ti == n_b - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        out = acc_sc[...] / l[..., None]
        o_ref[0, :, 0] = out.reshape(n_q, group, out.shape[-1]).astype(
            o_ref.dtype)


def paged_verify_attention_kernel(q, k_pool, v_pool, block_tables, q_off,
                                  *, interpret=False):
    """q: (B,S,K,G,Dh) — S speculative queries per row, query ``s`` at
    absolute position ``q_off[b] + s``; pools: (nb, block_size, K, Dh);
    block_tables: (B, mb) int32; q_off: (B,) int32 base positions."""
    B, S, K, G, Dh = q.shape
    nb, block_size = k_pool.shape[0], k_pool.shape[1]
    mb = block_tables.shape[1]
    scale = 1.0 / (Dh ** 0.5)

    kernel = functools.partial(_paged_verify_kernel, scale=scale,
                               block_size=block_size, n_b=mb, n_q=S,
                               group=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # q_off, block_tables
        grid=(B, K, mb),
        in_specs=[
            pl.BlockSpec((1, S, 1, G, Dh),
                         lambda b, h, ti, off, btab: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, block_size, 1, Dh),
                         lambda b, h, ti, off, btab: (btab[b, ti], 0, h, 0)),
            pl.BlockSpec((1, block_size, 1, Dh),
                         lambda b, h, ti, off, btab: (btab[b, ti], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, 1, G, Dh),
                               lambda b, h, ti, off, btab: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * G,), jnp.float32),
            pltpu.VMEM((S * G,), jnp.float32),
            pltpu.VMEM((S * G, Dh), jnp.float32),
        ],
    )
    off = jnp.broadcast_to(jnp.asarray(q_off, jnp.int32), (B,))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(off, block_tables.astype(jnp.int32), q, k_pool, v_pool)
