"""Blocked flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Layout: q is pre-reshaped to (B, K, G, S, Dh) and k/v to (B, K, T, Dh) so GQA
head grouping is a plain block dimension.  Grid = (B, K, nQ, nK); the last
grid axis iterates sequentially on TPU, so the online-softmax state
(m, l, acc) lives in VMEM scratch and is carried across kv blocks of one
(b, kv-head, q-block) cell, exactly like the reference TPU flash kernel.

Causal / sliding-window masking is applied per (q,k) block; blocks that are
entirely masked skip their matmuls via @pl.when (the kv grid is still full
size — the structural FLOP skip happens in ops.py by clamping nK per q-block
when the mask is causal, see `_kv_blocks_for`).

MXU alignment: block_q and block_k default to 128 (the MXU systolic dim);
Dh (64..256 for all assigned archs) rides along whole.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                 scale, causal, window, block_q, block_k, n_kv, t_total,
                 q_offset):
    """One (b, kv-head, qi, ki) grid cell."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    t_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    valid = t_pos < t_total
    if causal:
        valid &= t_pos <= q_pos
    if window is not None:
        valid &= t_pos > q_pos - window

    # any-valid test is cheap and static-shaped; fully-masked blocks skip
    # the matmuls entirely.
    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]                       # (G, block_q, Dh)
        k = k_ref[0, 0]                       # (block_k, Dh)
        v = v_ref[0, 0]                       # (block_k, Dh)
        s = jax.lax.dot_general(
            q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
            (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bq, bk)
        s = jnp.where(valid[None], s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, bq, Dh)
        acc_sc[...] = acc_sc[...] * alpha[..., None] + pv
        m_sc[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=None, q_offset=0,
                           block_q=128, block_k=128, interpret=False,
                           t_total=None):
    """q: (B,K,G,S,Dh); k,v: (B,K,T,Dh) -> (B,K,G,S,Dh).

    t_total: count of REAL kv rows (<= T) when k/v carry block padding —
    padded rows must not receive softmax mass in non-causal attention.
    """
    B, K, G, S, Dh = q.shape
    T = k.shape[2]
    t_total = T if t_total is None else t_total
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(T, block_k)
    scale = 1.0 / (Dh ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=nk, t_total=t_total,
        q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, Dh),
                         lambda b, h, qi, ki: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, block_q, Dh),
                               lambda b, h, qi, ki: (b, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # (G, block_q) running max / denom + (G, block_q, Dh) accumulator
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
