"""jit'd public wrapper for the flash-attention Pallas kernel.

Accepts model-layout tensors (q: (B,S,H,Dh); k/v: (B,T,K,Dh)), reshapes to
the kernel's GQA-grouped layout, and — when the mask is causal — clamps the
kv grid per q-block so fully-masked kv blocks are never launched (the
structural FLOP skip that the pure-JAX `chunked` path lacks).

On non-TPU backends the kernel runs in interpret mode (the Python body is
executed by the Pallas interpreter), which is exactly how the test suite
validates it against ref.py on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    block_q=128, block_k=128, interpret=None):
    """q: (B,S,H,Dh); k,v: (B,T,K,Dh) -> (B,S,H,Dh)."""
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()

    block_q = min(block_q, S)
    block_k = min(block_k, T)
    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    qg = q.reshape(B, S, K, G, Dh).transpose(0, 2, 3, 1, 4)   # (B,K,G,S,Dh)
    kg = k.transpose(0, 2, 1, 3)                              # (B,K,T,Dh)
    vg = v.transpose(0, 2, 1, 3)
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kg = jnp.pad(kg, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    o = flash_attention_kernel(qg, kg, vg, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret,
                               t_total=T)
    o = o[:, :, :, :S]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)
