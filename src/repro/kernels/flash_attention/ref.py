"""Pure-jnp oracle for blocked causal/GQA/SWA flash attention."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    """Dense reference attention.

    q: (B,S,H,Dh); k,v: (B,T,K,Dh) with H = G*K (GQA).  All math f32.
    Returns (B,S,H,Dh) in q.dtype.
    """
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / np.sqrt(Dh)
    q_pos = q_offset + jnp.arange(S)
    t_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= t_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= t_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return o.reshape(B, S, H, Dh).astype(q.dtype)
