from repro.kernels.grouped_matmul.ops import grouped_matmul  # noqa: F401
