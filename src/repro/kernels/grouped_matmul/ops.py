"""jit'd public wrapper for the grouped-matmul Pallas kernel.

`grouped_matmul` takes the ragged layout (rows sorted by expert +
group_sizes) and builds the per-tile expert map.  Group boundaries must be
block_m-aligned (the dense-padding contract); `pad_group_sizes` and the
capacity-bucket helper below produce aligned layouts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.grouped_matmul.kernel import grouped_matmul_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_group_sizes(group_sizes, block_m: int):
    """Round every group size up to a multiple of block_m."""
    return ((group_sizes + block_m - 1) // block_m) * block_m


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def grouped_matmul(x, w, group_sizes, *, block_m=128, block_n=128,
                   interpret=None):
    """x: (T,D) rows sorted by expert, each group block_m-aligned and padded
    with zero rows; w: (E,D,F); group_sizes: (E,) aligned sizes summing to
    <= T.  Returns (T,F) f32 (zero rows stay zero)."""
    T, D = x.shape
    E = w.shape[0]
    if interpret is None:
        interpret = not _on_tpu()
    n_tiles = T // block_m
    ends = jnp.cumsum(group_sizes)
    tile_starts = jnp.arange(n_tiles) * block_m
    # expert owning each row tile; tiles past all groups clamp to E-1 and
    # multiply against zero-padded x rows -> zero output.
    tile_ids = jnp.minimum(
        jnp.searchsorted(ends, tile_starts, side="right"), E - 1)
    return grouped_matmul_kernel(x, w, tile_ids, block_m=block_m,
                                 block_n=block_n, interpret=interpret)


def bucket_matmul(buckets, w, *, block_m=128, block_n=128, interpret=None):
    """Capacity-bucket layout (models/moe.py): buckets (E,C,D) -> (E,C,F).
    Equal group sizes C; requires C % block_m == 0 or C <= block_m."""
    E, C, D = buckets.shape
    bm = min(block_m, C)
    x = buckets.reshape(E * C, D)
    sizes = jnp.full((E,), C, jnp.int32)
    y = grouped_matmul(x, w, sizes, block_m=bm, block_n=block_n,
                       interpret=interpret)
    return y.reshape(E, C, w.shape[2])
