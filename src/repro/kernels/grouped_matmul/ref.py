"""Pure-jnp oracle for the MoE grouped (ragged expert) matmul."""

from __future__ import annotations

import jax.numpy as jnp


def row_expert_ids(group_sizes, n_rows: int):
    """group_sizes: (E,) -> (n_rows,) expert id per row (sorted layout)."""
    ends = jnp.cumsum(group_sizes)
    return jnp.searchsorted(ends, jnp.arange(n_rows), side="right")


def grouped_matmul_ref(x, w, group_sizes):
    """x: (T,D) rows sorted by expert; w: (E,D,F); group_sizes: (E,) summing
    to <= T (tail rows belong to no expert -> zero output).
    Returns (T,F) f32."""
    T = x.shape[0]
    E = w.shape[0]
    gid = row_expert_ids(group_sizes, T)
    valid = gid < E
    gid_c = jnp.where(valid, gid, 0)
    wg = jnp.take(w, gid_c, axis=0)                            # (T,D,F)
    y = jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                   wg.astype(jnp.float32))
    return y * valid[:, None]
