"""MoE grouped expert matmul (dense-padded group tiling) for TPU.

Tokens arrive sorted by expert with every group padded to a multiple of
block_m (the "dense padding" that trades a few zero rows for fully regular
MXU tiles — the TPU-native answer to GPU megablocks' ragged CSR tiling).
A per-row-tile expert id array rides in via scalar prefetch, and the weight
BlockSpec index_map selects the expert's (D, block_n) slab — so one kernel
instance streams x tiles while hopping expert weights without any gather.

Grid = (nM, nN); x tile (block_m, D) and w slab (D, block_n) both live in
VMEM; D (<= 4096 for all assigned MoE archs) rides whole, so each tile is a
single MXU matmul with no k-loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(gids_ref, x_ref, w_ref, y_ref):
    x = x_ref[...]                                            # (block_m, D)
    w = w_ref[0]                                              # (D, block_n)
    y_ref[...] = jax.lax.dot_general(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)


def grouped_matmul_kernel(x, w, tile_expert_ids, *, block_m=128, block_n=128,
                          interpret=False):
    """x: (T,D) with T % block_m == 0, rows sorted + padded by expert;
    w: (E,D,F); tile_expert_ids: (T/block_m,) int32.  Returns (T,F) f32."""
    T, D = x.shape
    E, _, F = w.shape
    assert T % block_m == 0, (T, block_m)
    block_n = min(block_n, F)
    assert F % block_n == 0, (F, block_n)
    grid = (T // block_m, F // block_n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi, ni, gids: (mi, 0)),
            pl.BlockSpec((1, D, block_n),
                         lambda mi, ni, gids: (gids[mi], 0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, gids: (mi, ni)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), jnp.float32),
        interpret=interpret,
    )(tile_expert_ids.astype(jnp.int32), x, w)
