"""jit'd public wrapper for the SSD-scan Pallas kernel.

Model layout (models/ssm.py) is x: (b,S,H,P), dt: (b,S,H), B/C: (b,S,G,N);
the kernel wants the head axis outermost and the sequence padded to the
chunk size.  Padding uses dt=0 (decay exp(0)=1, zero state contribution) so
the carried state is exact regardless of padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=None):
    """x: (b,S,H,P); dt: (b,S,H); A: (H,); B,C: (b,S,G,N).
    Returns (y (b,S,H,P), final_state (b,H,N,P) f32)."""
    b, S, H, P = x.shape
    if interpret is None:
        interpret = not _on_tpu()
    chunk = min(chunk, S) if S % chunk else chunk
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xk = x.transpose(0, 2, 1, 3)                   # (b,H,S,P)
    dtk = dt.transpose(0, 2, 1)                    # (b,H,S)
    Bk = B.transpose(0, 2, 1, 3)                   # (b,G,S,N)
    Ck = C.transpose(0, 2, 1, 3)
    y, s_final = ssd_scan_kernel(xk, dtk, A, Bk, Ck, chunk=chunk,
                                 interpret=interpret)
    y = y.transpose(0, 2, 1, 3)
    return (y[:, :S] if pad else y), s_final
