"""Sequential-recurrence oracle for the Mamba-2 SSD scan.

Deliberately the *naive* per-token recurrence (lax.scan over S) — an
independent formulation from both the chunked jnp path (models/ssm.py) and
the Pallas kernel, so agreement between all three is meaningful.

    state_t = exp(dt_t * A_h) * state_{t-1} + dt_t * B_t (outer) x_t
    y_t     = C_t . state_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, B, C):
    """x: (b,S,H,P); dt: (b,S,H) post-softplus; A: (H,) negative;
    B, C: (b,S,G,N) with H % G == 0.
    Returns (y (b,S,H,P) f32, final_state (b,H,N,P) f32)."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)        # (b,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                                  # (b,H,*) each
        decay = jnp.exp(dtt * A.astype(jnp.float32))           # (b,H)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt, xt)
        state = decay[..., None, None] * state + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ct, state)
        return state, y

    init = jnp.zeros((b, H, N, P), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3), state
