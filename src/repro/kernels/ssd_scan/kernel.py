"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

The state-space-duality form turns the selective scan into MXU work: within
a chunk of Q tokens everything is (Q,Q)/(Q,N)/(N,P) matmuls; only the
(N,P) running state crosses chunk boundaries.  Grid = (B, H, nChunks); the
chunk axis iterates sequentially on TPU so the state lives in VMEM scratch —
no HBM round-trip for the recurrence, which is the entire point of adapting
the GPU selective-scan to the TPU memory hierarchy.

Per-chunk math (all f32 in VMEM):
    dA    = dt * A_h                       (Q,)
    cum   = inclusive cumsum(dA)           (Q,)
    L     = exp(cum_q - cum_j) masked to j<=q
    y     = ((C B^T) . L) @ (dt * x)       intra-chunk, (Q,P)
          + exp(cum) * (C @ state)         inter-chunk carry-in
    state = exp(cum_Q) * state + B^T @ (dt * exp(cum_Q - cum) * x)

VMEM tiling: Q=chunk (default 128) and N=state_dim (128) are lane-aligned;
P=head_dim (64) rides whole.  A arrives via scalar prefetch (SMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_final_ref,
                state_sc, *, chunk, n_chunks):
    h = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_sc[...] = jnp.zeros_like(state_sc)

    A = a_ref[h]                                              # scalar
    x = x_ref[0, 0].astype(jnp.float32)                       # (Q,P)
    dt = dt_ref[0, 0].astype(jnp.float32)                     # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)                       # (Q,N)
    C = c_ref[0, 0].astype(jnp.float32)                       # (Q,N)

    dA = dt * A                                               # (Q,) <= 0
    cum = jnp.cumsum(dA)                                      # (Q,)
    total = cum[-1]

    # ---- intra-chunk (Q,Q) masked decay matmul
    seg = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ji = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(qi >= ji, seg, -jnp.inf)
    L = jnp.exp(seg)                                          # (Q,Q)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]                                     # (Q,P)
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- inter-chunk carry-in
    state = state_sc[...]                                     # (N,P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # ---- state update
    decay_out = jnp.exp(total - cum)                          # (Q,)
    S_loc = jax.lax.dot_general(
        B, x * (dt * decay_out)[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (N,P)
    state_sc[...] = jnp.exp(total) * state + S_loc

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_final_ref[0, 0] = state_sc[...]


def ssd_scan_kernel(x, dt, A, B, C, *, chunk=128, interpret=False):
    """x: (b,H,S,P); dt: (b,H,S); A: (H,); B,C: (b,G,S,N), H % G == 0.
    Returns (y (b,H,S,P) x.dtype, final_state (b,H,N,P) f32)."""
    b, H, S, P = x.shape
    G, N = B.shape[1], B.shape[3]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    rep = H // G

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, ci, a: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, h, ci, a: (bi, h, ci)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bi, h, ci, a: (bi, h // rep, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bi, h, ci, a: (bi, h // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, ci, a: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, h, ci, a: (bi, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, B, C)
