"""jit'd public wrapper for the flash-decode Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, *, block_t=128,
                     interpret=None):
    """q: (B,H,Dh) one new token per sequence; caches: (B,T,K,Dh);
    cache_len: scalar or (B,) valid-entry count.  Returns (B,H,Dh).

    Per-row (ragged) lengths are the continuous-batching serve path: each
    batch row is an independent request at its own position, so the lens
    vector arrives via scalar prefetch and the kernel masks each row's KV
    tail without recompiling (fully-masked tiles skip their compute)."""
    B, H, Dh = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()
    qg = q.reshape(B, K, G, Dh)
    kg = k_cache.transpose(0, 2, 1, 3)                        # (B,K,T,Dh)
    vg = v_cache.transpose(0, 2, 1, 3)
    o = decode_attention_kernel(qg, kg, vg, cache_len, block_t=block_t,
                                interpret=interpret)
    return o.reshape(B, H, Dh)
