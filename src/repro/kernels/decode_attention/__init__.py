from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
