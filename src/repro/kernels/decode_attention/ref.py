"""Pure-jnp oracle for single-token decode attention over a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """q: (B,H,Dh); caches: (B,T,K,Dh); cache_len: scalar or (B,) valid count.
    Returns (B,H,Dh) f32-accurate attention output in q.dtype."""
    B, H, Dh = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, K, G, Dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k_cache.astype(jnp.float32))
    s = s / np.sqrt(Dh)
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    valid = jnp.arange(T)[None] < cl[:, None]                  # (B,T)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)
