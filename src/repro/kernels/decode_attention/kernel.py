"""Flash-decode: single-token attention against a long KV cache, split over
the sequence (split-K) so the dominant loop streams the cache through VMEM
in lane-aligned 128-token tiles.

Grid = (B, K, nS).  The last axis iterates sequentially on TPU, carrying the
online-softmax state in VMEM scratch; device-level split-K parallelism comes
from sharding the cache's T dim over the "model" mesh axis (the partial
max/sum then combine with all-reduces inserted by SPMD — see
models/attention.py `decode_attend`).  Within a chip this kernel is the
per-shard inner loop.

cache_len arrives via scalar prefetch (SMEM) so masking is dynamic without
re-compilation per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
                   *, scale, block_t, n_t):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    b = pl.program_id(0)
    t_pos = ti * block_t + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)
    valid = (t_pos < len_ref[b])[0]                           # (block_t,)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0]                                       # (G, Dh)
        k = k_ref[0, 0]                                       # (block_t, Dh)
        # zero invalid rows: when T % block_t != 0 the final block reads
        # out-of-bounds rows (NaN in interpret mode); their p weight is 0
        # but 0*NaN would still poison the p@v contraction.
        v = jnp.where(valid[:, None], v_ref[0, 0], 0.0)
        s = jax.lax.dot_general(
            q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (G, block_t)
        s = jnp.where(valid[None], s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, Dh)
        acc_sc[...] = acc_sc[...] * alpha[..., None] + pv
        m_sc[...] = m_new

    @pl.when(ti == n_t - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, cache_len, *, block_t=128,
                            interpret=False):
    """q: (B,K,G,Dh); caches: (B,K,T,Dh); cache_len: (B,) int32."""
    B, K, G, Dh = q.shape
    T = k_cache.shape[2]
    block_t = min(block_t, T)
    n_t = pl.cdiv(T, block_t)
    scale = 1.0 / (Dh ** 0.5)

    kernel = functools.partial(_decode_kernel, scale=scale, block_t=block_t,
                               n_t=n_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, ti, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_t, Dh),
                         lambda b, h, ti, lens: (b, h, ti, 0)),
            pl.BlockSpec((1, 1, block_t, Dh),
                         lambda b, h, ti, lens: (b, h, ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, ti, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    # scalar-prefetch operand indexed per grid cell b
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lens, q, k_cache, v_cache)
