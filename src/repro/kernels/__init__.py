# Pallas TPU kernels for the compute hot-spots (validated in interpret mode
# on CPU; selected via ArchConfig attn_impl / ssm_impl / moe_impl / norm_impl):
#   flash_attention  — blocked causal/GQA/SWA attention (train/prefill)
#   decode_attention — flash-decode split-K over the dense KV cache (serve)
#   paged_attention  — flash-decode over a block-pool KV cache: block
#                      tables arrive via scalar prefetch and pick the
#                      physical block each grid step streams into VMEM
#   ssd_scan         — Mamba-2 chunked state-space scan
#   grouped_matmul   — MoE ragged expert matmul (dense-padded tiling)
#   rmsnorm          — fused residual+RMSNorm (memory-bound fusion)
