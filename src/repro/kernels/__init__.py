# Pallas TPU kernels for the compute hot-spots (validated in interpret mode
# on CPU; selected via ArchConfig attn_impl / ssm_impl / moe_impl / norm_impl):
#   flash_attention  — blocked causal/GQA/SWA attention (train/prefill)
#   decode_attention — flash-decode split-K over the KV cache (serve)
#   ssd_scan         — Mamba-2 chunked state-space scan
#   grouped_matmul   — MoE ragged expert matmul (dense-padded tiling)
#   rmsnorm          — fused residual+RMSNorm (memory-bound fusion)
