from repro.kernels.rmsnorm.ops import rmsnorm_fused  # noqa: F401
