"""jit'd public wrapper for the fused RMSNorm Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "block_r", "interpret"))
def rmsnorm_fused(x, scale, residual=None, *, eps=1e-5, block_r=256,
                  interpret=None):
    """x: (..., D); scale: (D,); optional residual of x's shape.
    Returns (normed, residual_out), both shaped like x."""
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    D = shape[-1]
    R = 1
    for s in shape[:-1]:
        R *= s
    x2 = x.reshape(R, D)
    r2 = residual.reshape(R, D) if residual is not None else None
    block = block_r
    while R % block:
        block //= 2
    block = max(block, 1)
    o, res = rmsnorm_kernel(x2, scale, r2, eps=eps, block_r=block,
                            interpret=interpret)
    return o.reshape(shape), res.reshape(shape)
