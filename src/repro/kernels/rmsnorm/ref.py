"""Pure-jnp oracle for fused residual-add + RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, residual=None, eps=1e-5):
    """x: (..., D); scale: (D,) storing (gamma - 1) like models/layers.py.
    Returns (normed, residual_out) where residual_out = x + residual (the
    pre-norm skip) — both in x.dtype."""
    if residual is not None:
        x = x + residual
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype), x
