"""Fused residual-add + RMSNorm Pallas kernel.

Memory-bound fusion: the unfused HLO reads x twice (residual add, then norm)
and round-trips the sum through HBM; fusing keeps the row in VMEM and writes
both outputs (normed + new residual stream) in one pass — exactly the
"memory term" optimization the roofline analysis flags for norm-heavy archs
(minicpm3: 62 layers x 2 norms).

Grid = (nRows,); block (block_r, D) rows in VMEM; reductions in f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, res_ref, *, eps, has_residual,
                    res_in_ref=None):
    x = x_ref[...].astype(jnp.float32)                        # (block_r, D)
    if has_residual:
        x = x + res_in_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    normed = normed * (1.0 + scale_ref[...].astype(jnp.float32))
    o_ref[...] = normed.astype(o_ref.dtype)
    res_ref[...] = x.astype(res_ref.dtype)


def rmsnorm_kernel(x, scale, residual=None, *, eps=1e-5, block_r=256,
                   interpret=False):
    """x: (R,D); scale: (D,); residual: (R,D) or None.
    Returns (normed (R,D), residual_out (R,D))."""
    R, D = x.shape
    block_r = min(block_r, R)
    assert R % block_r == 0, (R, block_r)
    grid = (R // block_r,)
    has_residual = residual is not None

    if has_residual:
        def kernel(x_ref, res_in_ref, scale_ref, o_ref, res_ref):
            _rmsnorm_kernel(x_ref, scale_ref, o_ref, res_ref, eps=eps,
                            has_residual=True, res_in_ref=res_in_ref)
        in_specs = [
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ]
        args = (x, residual, scale)
    else:
        def kernel(x_ref, scale_ref, o_ref, res_ref):
            _rmsnorm_kernel(x_ref, scale_ref, o_ref, res_ref, eps=eps,
                            has_residual=False)
        in_specs = [
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ]
        args = (x, scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), x.dtype),
            jax.ShapeDtypeStruct((R, D), x.dtype),
        ],
        interpret=interpret,
    )(*args)
