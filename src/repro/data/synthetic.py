"""Deterministic synthetic packed-token data pipeline.

Produces language-model batches with a learnable structure (a noisy
second-order Markov stream) so training loss measurably decreases — enough
signal to validate end-to-end training without external data.  Batches are
generated shard-by-shard on the host and placed directly into the sharded
global array layout (no full-batch host materialization), which is the same
code path a multi-host loader would use per process.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding


@dataclasses.dataclass
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8      # prob. of following the Markov rule


class SyntheticLM:
    """Iterator of {"tokens", "targets"} batches."""

    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed random bigram successor table: t+1 = table[t] with prob p
        self._table = rng.integers(0, v, size=(v,), dtype=np.int32)
        self._step = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=B)
        follow = rng.random((B, S)) < cfg.structure
        noise = rng.integers(0, v, size=(B, S), dtype=np.int32)
        for t in range(S):
            nxt = self._table[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        return self

    def __next__(self):
        b = self.batch_at(self._step)
        self._step += 1
        return b


def device_put_batch(batch: dict[str, np.ndarray], shardings) -> dict:
    """Place host batch into the sharded global layout."""
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else jnp.asarray(v)
        for k, v in batch.items()
    }
