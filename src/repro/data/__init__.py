from repro.data.synthetic import SyntheticConfig, SyntheticLM, device_put_batch

__all__ = ["SyntheticConfig", "SyntheticLM", "device_put_batch"]
