from repro.ckpt.checkpoint import (
    AsyncCheckpointer, all_steps, latest_step, restore, save,
)

__all__ = ["AsyncCheckpointer", "all_steps", "latest_step", "restore", "save"]
