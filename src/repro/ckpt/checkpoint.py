"""Sharded numpy checkpointing: atomic, async, keep-last-k, resumable.

Layout:   <dir>/step_<N>/ {manifest.json, leaf_<i>.npy ...}
          <dir>/LATEST  (atomic pointer file)

Leaves are gathered to host (process-local here; in a true multi-host
deployment each process writes its addressable shards — the manifest format
already records per-leaf paths so that extension is additive).  Writes go to
a tmp dir first and are renamed into place, so a pilot killed mid-write can
never corrupt the latest checkpoint — the fault-tolerance contract the
pilot's checkpoint/restart story depends on.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Blocking save.  Returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}_{threading.get_ident()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
                "time": time.time()}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _point_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep)
    return final


def _point_latest(ckpt_dir: str, step: int):
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.startswith(".tmp"):
            try:
                out.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(p):
        try:
            s = int(open(p).read().strip())
            if os.path.isdir(os.path.join(ckpt_dir, f"step_{s}")):
                return s
        except ValueError:
            pass
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs).  Optionally device_put with `shardings`."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    leaves, treedef = _flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: ckpt shape {arr.shape} != {ref.shape}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


class AsyncCheckpointer:
    """Fire-and-forget background saves; at most one in flight (newer saves
    queue behind; superseded queued saves are dropped)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: tuple[int, object] | None = None
        self._thread: threading.Thread | None = None
        self._running = False       # exit/restart decisions share the lock
        self.errors: list[Exception] = []

    def save(self, step: int, tree):
        # snapshot to host synchronously (cheap vs device compute), write async
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)
        with self._lock:
            self._pending = (step, snap)
            if not self._running:
                self._running = True
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                item, self._pending = self._pending, None
                if item is None:
                    self._running = False
                    return
            try:
                save(self.ckpt_dir, item[0], item[1], keep=self.keep)
            except Exception as e:      # surfaced via .errors + wait()
                self.errors.append(e)

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()
        if self.errors:
            raise self.errors[-1]
