"""Sharded numpy checkpointing: atomic, async, keep-last-k, resumable.

Layout:   <dir>/step_<N>/ {manifest.json, leaf_<i>.npy ...}
          <dir>/LATEST  (atomic pointer file)

Leaves are gathered to host (process-local here; in a true multi-host
deployment each process writes its addressable shards — the manifest format
already records per-leaf paths so that extension is additive).  Writes go to
a tmp dir first and are renamed into place, so a pilot killed mid-write can
never corrupt the latest checkpoint — the fault-tolerance contract the
pilot's checkpoint/restart story depends on.

Overwriting an existing ``step_N`` never deletes before the replacement is
in place: the old dir is renamed aside (``.retired_step_N_*``), the tmp dir
renamed in, and only then is the retired dir removed.  A crash anywhere in
that window leaves either the new or the OLD data recoverable —
``_sweep_retired`` (run by ``save``/``latest_step``/``all_steps``) renames
an orphaned retired dir back into place, so ``latest_step`` always resolves
to a restorable checkpoint.  (The previous rmtree-then-rename order had a
window where a crash destroyed ``step_N`` while ``LATEST`` still pointed at
it.)

``restore`` validates leaf dtypes as well as shapes: a float64 ``.npy``
silently loading into a bf16-typed state would poison every downstream
compilation cache keyed on the state's dtypes.  Pass ``cast=True`` to
convert explicitly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro.analysis.locks import make_lock


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


_RETIRED_PREFIX = ".retired_step_"


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Blocking save.  Returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}_{threading.get_ident()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
                "time": time.time()}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        # never a moment without a complete step_N on disk: retire the old
        # dir aside, move the new one in, THEN delete.  A crash between the
        # renames leaves the retired dir for _sweep_retired to reinstate.
        # The retire TIME rides in the name — os.rename preserves mtime, so
        # the dir's own timestamps say when the checkpoint was written, not
        # when it was retired, and the sweep's live-writer grace window
        # needs the latter.
        retired = os.path.join(
            ckpt_dir,
            f"{_RETIRED_PREFIX}{step}_{int(time.time() * 1000)}"
            f"_{os.getpid()}_{threading.get_ident()}")
        os.rename(final, retired)
        os.rename(tmp, final)
        shutil.rmtree(retired, ignore_errors=True)
    else:
        os.rename(tmp, final)
    _point_latest(ckpt_dir, step)
    _gc(ckpt_dir, keep)        # its all_steps() listing also runs the sweep
    return final


def _sweep_retired(ckpt_dir: str, *, min_age_s: float = 2.0):
    """Crash recovery for the overwrite window: a ``.retired_step_N_*`` dir
    whose ``step_N`` is missing means the writer died between the two
    renames — put the old (complete, valid) checkpoint back.  If ``step_N``
    exists, the crash happened after the replacement landed and the retired
    dir is garbage.

    The reinstate branch only fires for dirs RETIRED more than ``min_age_s``
    ago (the retire time is parsed from the dir name — rename preserves
    mtime, so the filesystem timestamps are useless here): a HEALTHY
    writer's retire→rename window is microseconds, so a fresh retired dir
    most likely belongs to a live save on another thread or process —
    renaming it back mid-window would make that writer's
    ``os.rename(tmp, final)`` hit an existing directory and fail."""
    if not os.path.isdir(ckpt_dir):
        return
    for d in os.listdir(ckpt_dir):
        if not d.startswith(_RETIRED_PREFIX):
            continue
        parts = d[len(_RETIRED_PREFIX):].split("_")
        try:
            step = int(parts[0])
            retired_at = int(parts[1]) / 1000.0
        except (ValueError, IndexError):
            continue
        path = os.path.join(ckpt_dir, d)
        final = os.path.join(ckpt_dir, f"step_{step}")
        try:
            if os.path.isdir(final):
                shutil.rmtree(path, ignore_errors=True)
            elif time.time() - retired_at >= min_age_s:
                os.rename(path, final)
        except OSError:
            continue                       # a concurrent sweeper (or the
                                           # writer itself) won the rename


def _point_latest(ckpt_dir: str, step: int):
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    _sweep_retired(ckpt_dir)
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.startswith(".tmp"):
            try:
                out.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> int | None:
    _sweep_retired(ckpt_dir)
    p = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(p):
        try:
            s = int(open(p).read().strip())
            if os.path.isdir(os.path.join(ckpt_dir, f"step_{s}")):
                return s
        except ValueError:
            pass
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None, *, cast: bool = False):
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs).  Optionally device_put with `shardings`.

    Leaf shapes AND dtypes must match ``like``; a dtype mismatch raises
    (a float64 ``.npy`` silently loading into a bf16 state would poison
    downstream compilation caches).  ``cast=True`` opts into an explicit
    ``astype`` to the reference dtype instead."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    leaves, treedef = _flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: ckpt shape {arr.shape} != {ref.shape}")
        ref_dtype = getattr(ref, "dtype", None)
        if ref_dtype is not None and arr.dtype != np.dtype(ref_dtype):
            if not cast:
                raise ValueError(
                    f"leaf {i}: ckpt dtype {arr.dtype} != expected "
                    f"{np.dtype(ref_dtype)} (pass cast=True to convert "
                    f"explicitly)")
            arr = arr.astype(ref_dtype)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


class AsyncCheckpointer:
    """Fire-and-forget background saves; at most one in flight (newer saves
    queue behind; superseded queued saves are dropped)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._lock = make_lock("ckpt.async-writer")
        self._pending: tuple[int, object] | None = None
        self._thread: threading.Thread | None = None
        self._running = False       # exit/restart decisions share the lock
        self.errors: list[Exception] = []

    def save(self, step: int, tree):
        # snapshot to host synchronously (cheap vs device compute), write async
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)
        with self._lock:
            self._pending = (step, snap)
            if not self._running:
                self._running = True
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                item, self._pending = self._pending, None
                if item is None:
                    self._running = False
                    return
            try:
                save(self.ckpt_dir, item[0], item[1], keep=self.keep)
            except Exception as e:      # surfaced via .errors + wait()
                self.errors.append(e)

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()
        if self.errors:
            raise self.errors[-1]
