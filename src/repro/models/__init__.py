from repro.models.api import ModelBundle, build_model, init_decode_state

__all__ = ["ModelBundle", "build_model", "init_decode_state"]
