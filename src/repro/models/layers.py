"""Shared building blocks: norms, RoPE, MLPs, embeddings, losses.

Everything is a pure function over explicit param pytrees (no flax).  Params
are stored in ``param_dtype`` (f32 for training, bf16 for serving) and cast to
``compute_dtype`` at the point of use; reductions that need precision (norm
variance, softmax, loss) run in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import constrain_replicated


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches maxtext/llama defaults)."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(key, cfg, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}   # rmsnorm stores (scale-1)


def apply_norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    if getattr(cfg, "norm_impl", "jnp") == "pallas":
        from repro.kernels.rmsnorm.ops import rmsnorm_fused
        return rmsnorm_fused(x, p["scale"], eps=cfg.norm_eps)[0]
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_table(positions, dim: int, theta: float):
    """cos/sin tables for `positions` (any shape) and head sub-dim `dim`."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, dim); cos/sin: (seq, dim/2), (B, seq, dim/2)
    (per-row positions — continuous-batching decode), or broadcastable."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:      # (S, dim/2) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    elif cos.ndim == 3:    # (B, S, dim/2) -> broadcast over heads
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def init_mlp(key, cfg):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], (D, F)), "down": dense_init(ks[1], (F, D))}
    if cfg.mlp_gated:
        p["gate"] = dense_init(ks[2], (D, F))
    return p


def apply_mlp(x, p, cfg, compute_dtype=jnp.bfloat16):
    act = act_fn(cfg.activation)
    up = jnp.einsum("bsd,df->bsf", x, p["up"].astype(compute_dtype))
    if cfg.mlp_gated:
        gate = jnp.einsum("bsd,df->bsf", x, p["gate"].astype(compute_dtype))
        h = act(gate) * up
    else:
        h = act(up)
    # serve TP: h is d_ff-sharded (up/gate column-parallel); gather it so
    # the down contraction keeps single-device reduction order
    h = constrain_replicated(h)
    return jnp.einsum("bsf,fd->bsd", h, p["down"].astype(compute_dtype))


# --------------------------------------------------------------------------
# Embedding / head / loss
# --------------------------------------------------------------------------

def embed_lookup(tokens, table, compute_dtype=jnp.bfloat16):
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def lm_logits(x, head, softcap: float | None = None):
    """x: (B,S,D) compute dtype; head: (D,V).  Returns f32 logits."""
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)).astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    # serve TP: head is vocab-sharded (column-parallel); gather so the
    # engine's argmax/top-k run on replicated logits
    return constrain_replicated(logits)


def softmax_cross_entropy(logits, targets, mask=None):
    """logits (B,S,V) f32, targets (B,S) int32 -> scalar mean loss."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def softmax_cross_entropy_fused(h, head, targets, *, softcap=None, mask=None,
                                chunk: int = 1024):
    """Mean CE of ``logits = h @ head`` WITHOUT materializing (B,S,V).

    The full logits tensor is the single largest intermediate of an LM train
    step (gemma train_4k: 256x4096x256000 f32 = 1 PB global).  We scan the
    sequence in `chunk`-token slices: each slice's (B,c,V) logits is a scan
    temporary, and jax.checkpoint on the body recomputes it in backward, so
    peak memory is one chunk instead of the whole sequence.  With the head's
    V dim sharded over "model" and B over ("pod","data"), the per-chunk
    logsumexp lowers to one small all-reduce per chunk.

    h: (B,S,D) compute dtype; head: (D,V); targets: (B,S) int32.
    """
    B, S, D = h.shape
    if S <= chunk:
        return softmax_cross_entropy(lm_logits(h, head, softcap), targets, mask)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = h.shape[1] // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, D), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        from repro.runtime.sharding import constrain
        tot, cnt = carry
        hb, tb, mb = inp
        hb = constrain(hb, "b..")
        logits = lm_logits(hb, head, softcap)            # (B,c,V) temporary
        logits = constrain(logits, "b.m")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mb
        return (tot + jnp.sum(nll), cnt + jnp.sum(mb)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)
