"""Whisper-style encoder-decoder.

The conv/mel audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, frames, D) — equivalent to the
output of whisper's two conv layers.  The encoder runs bidirectional
self-attention over the frames; the decoder is a causal LM with an extra
cross-attention sub-layer per layer.

Decode shapes lower the DECODER step: one new token against a self-attn KV
cache of seq_len plus fixed cross-attn K/V precomputed from the encoder
output at prefill time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    apply_mlp, apply_norm, embed_init, embed_lookup, init_mlp, init_norm,
    lm_logits, rope_table, softmax_cross_entropy_fused,
)
from repro.models.transformer import _remat, head_matrix
from repro.runtime.sharding import constrain


def _sinusoidal(S: int, D: int):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_encdec_params(cfg, key):
    ks = jax.random.split(key, 8)
    enc_keys = jax.random.split(ks[0], (cfg.encoder_layers, 2))
    dec_keys = jax.random.split(ks[1], (cfg.num_layers, 3))

    def init_enc_layer(k):
        k1, k2 = k
        return {
            "attn_norm": init_norm(k1, cfg),
            "attn": attn.init_attention(k1, cfg),
            "ffn_norm": init_norm(k2, cfg),
            "ffn": init_mlp(k2, cfg),
        }

    def init_dec_layer(k):
        k1, k2, k3 = k
        return {
            "self_norm": init_norm(k1, cfg),
            "self_attn": attn.init_attention(k1, cfg),
            "cross_norm": init_norm(k2, cfg),
            "cross_attn": attn.init_attention(k2, cfg),
            "ffn_norm": init_norm(k3, cfg),
            "ffn": init_mlp(k3, cfg),
        }

    return {
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model)),
        "enc_layers": jax.vmap(init_enc_layer)(enc_keys),
        "enc_norm": init_norm(ks[3], cfg),
        "dec_layers": jax.vmap(init_dec_layer)(dec_keys),
        "final_norm": init_norm(ks[4], cfg),
    }


def encode(params, cfg, frames, *, compute=jnp.bfloat16):
    """frames: (B, F, D) stub embeddings -> (B, F, D) encoder output."""
    x = frames.astype(compute) + _sinusoidal(
        frames.shape[1], cfg.d_model).astype(compute)

    def body(x, p):
        x = constrain(x, "b..")
        h = apply_norm(x, p["attn_norm"], cfg)
        h = attn.attention_forward(h, p["attn"], cfg, rope_cos=None,
                                   rope_sin=None, causal=False,
                                   compute=compute)
        x = x + h
        h = apply_norm(x, p["ffn_norm"], cfg)
        x = x + apply_mlp(h, p["ffn"], cfg, compute)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_layers"])
    return apply_norm(x, params["enc_norm"], cfg)


def _decoder_stack(params, cfg, x, enc_out, compute):
    S = x.shape[1]
    rope = rope_table(jnp.arange(S), cfg.head_dim, cfg.rope_theta)

    def body(x, p):
        x = constrain(x, "b..")
        h = apply_norm(x, p["self_norm"], cfg)
        h = attn.attention_forward(h, p["self_attn"], cfg, rope_cos=rope[0],
                                   rope_sin=rope[1], causal=True,
                                   compute=compute)
        x = x + h
        h = apply_norm(x, p["cross_norm"], cfg)
        h = attn.attention_forward(h, p["cross_attn"], cfg, rope_cos=None,
                                   rope_sin=None, causal=False, kv=enc_out,
                                   compute=compute)
        x = x + h
        h = apply_norm(x, p["ffn_norm"], cfg)
        x = x + apply_mlp(h, p["ffn"], cfg, compute)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_layers"])
    return apply_norm(x, params["final_norm"], cfg)


def encdec_loss(params, cfg, frames, tokens, targets, *, compute=jnp.bfloat16):
    enc_out = encode(params, cfg, frames, compute=compute)
    x = embed_lookup(tokens, params["embed"], compute)
    h = _decoder_stack(params, cfg, x, enc_out, compute)
    ce = softmax_cross_entropy_fused(h, head_matrix(params, cfg), targets,
                                     softcap=cfg.logit_softcap,
                                     chunk=cfg.loss_chunk)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# --------------------------------------------------------------------------
# Prefill / decode
# --------------------------------------------------------------------------

def init_encdec_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-decoder-layer self-attn KV cache + fixed cross-attn K/V."""
    L = cfg.num_layers
    K, Dh, F = cfg.num_kv_heads, cfg.head_dim, cfg.frontend_tokens
    tile = lambda a: jnp.broadcast_to(a[None], (L,) + a.shape)
    return {
        "self": {
            "k": tile(jnp.zeros((batch, max_len, K, Dh), dtype)),
            "v": tile(jnp.zeros((batch, max_len, K, Dh), dtype)),
        },
        "cross": {
            "k": tile(jnp.zeros((batch, F, K, Dh), dtype)),
            "v": tile(jnp.zeros((batch, F, K, Dh), dtype)),
        },
    }


def encdec_prefill(params, cfg, frames, tokens, cache, *, compute=jnp.bfloat16):
    """Encoder pass + decoder prefill; fills self + cross caches."""
    enc_out = encode(params, cfg, frames, compute=compute)
    x = embed_lookup(tokens, params["embed"], compute)
    S = x.shape[1]
    rope = rope_table(jnp.arange(S), cfg.head_dim, cfg.rope_theta)

    def body(x, inp):
        p, gcache = inp
        x = constrain(x, "b..")
        h = apply_norm(x, p["self_norm"], cfg)
        out, self_c = attn.attention_prefill(h, p["self_attn"], cfg, rope,
                                             gcache["self"], compute=compute)
        x = x + out
        h = apply_norm(x, p["cross_norm"], cfg)
        ck = jnp.einsum("bfd,dhk->bfhk", enc_out,
                        p["cross_attn"]["wk"].astype(compute))
        cv = jnp.einsum("bfd,dhk->bfhk", enc_out,
                        p["cross_attn"]["wv"].astype(compute))
        h = attn.attention_forward(h, p["cross_attn"], cfg, rope_cos=None,
                                   rope_sin=None, causal=False, kv=enc_out,
                                   compute=compute)
        x = x + h
        h = apply_norm(x, p["ffn_norm"], cfg)
        x = x + apply_mlp(h, p["ffn"], cfg, compute)
        cross_c = {"k": ck.astype(gcache["cross"]["k"].dtype),
                   "v": cv.astype(gcache["cross"]["v"].dtype)}
        return x, {"self": self_c, "cross": cross_c}

    x, new_cache = jax.lax.scan(_remat(body, cfg), x,
                                (params["dec_layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(x[:, -1:], head_matrix(params, cfg), cfg.logit_softcap)
    return logits, new_cache


def encdec_decode(params, cfg, token, cache, pos, *, compute=jnp.bfloat16):
    """One decoder step against self + cross caches.  pos: scalar or (B,)
    per-row absolute positions (continuous batching)."""
    x = embed_lookup(token, params["embed"], compute)

    def body(x, inp):
        p, gcache = inp
        x = constrain(x, "b..")
        h = apply_norm(x, p["self_norm"], cfg)
        h, self_c = attn.attention_decode(h, p["self_attn"], cfg,
                                          gcache["self"], pos, compute=compute)
        x = x + h
        h = apply_norm(x, p["cross_norm"], cfg)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"].astype(compute))
        F = gcache["cross"]["k"].shape[1]
        out = attn.decode_attend(q, gcache["cross"]["k"], gcache["cross"]["v"],
                                 jnp.int32(F))
        h = jnp.einsum("bshk,hkd->bsd", out,
                       p["cross_attn"]["wo"].astype(compute))
        x = x + h
        h = apply_norm(x, p["ffn_norm"], cfg)
        x = x + apply_mlp(h, p["ffn"], cfg, compute)
        return x, {"self": self_c, "cross": gcache["cross"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(x, head_matrix(params, cfg), cfg.logit_softcap)
    return logits, new_cache
