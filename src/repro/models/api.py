"""Public model API: one entry point per (arch family x mode).

``build_model(cfg)`` returns a :class:`ModelBundle` whose members are pure
functions suitable for jit / pjit / AOT lowering:

* ``init(key)``                      -> params
* ``loss(params, batch)``            -> (scalar, metrics)        [train]
* ``prefill(params, batch)``         -> (last logits, cache)     [prefill]
* ``decode(params, state)``          -> (logits, new state)      [decode]
* ``train_batch_specs(shape)``       -> ShapeDtypeStruct pytree
* ``decode_state_specs(shape)``      -> ShapeDtypeStruct pytree

The bundle is exactly what the pilot system's :class:`PayloadImage` compiles
when a payload is late-bound onto a slice.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf


def _text_len(cfg: ArchConfig, seq_len: int) -> int:
    """VLM stubs spend part of the assigned seq budget on patch embeds."""
    if cfg.family == "vlm":
        return seq_len - cfg.frontend_tokens
    return seq_len


def _has_frontend(cfg: ArchConfig) -> bool:
    return cfg.family in ("vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable[[Any], Any]
    loss: Callable[[Any, Any], Any]
    prefill: Callable[[Any, Any], Any]
    decode: Callable[[Any, Any], Any]
    # (params, state, tokens (1,C), table_row (mb,), slot, q_offset)
    # -> (logits (1,V), state) — one chunk of an admission prefill into one
    # row of a PAGED decode state; None for families without a chunked
    # path (enc-dec).
    prefill_chunk: Callable[..., Any] | None = None
    # (params, tokens (B,S), state) -> (logits (B,S,V), new state) — the
    # speculative-verify forward: score S = k+1 consecutive positions
    # (pending token + k draft proposals) of every row in one pass over
    # the PAGED cache.  Each position's logits are bitwise-equal to the
    # sequential decode steps the verify replaces.  None for enc-dec.
    verify: Callable[..., Any] | None = None

    # ---- shape specs (ShapeDtypeStruct stand-ins; no allocation) ----------

    def train_batch_specs(self, shape: ShapeSpec, compute=jnp.bfloat16):
        cfg = self.cfg
        B = shape.global_batch
        S = _text_len(cfg, shape.seq_len)
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if _has_frontend(cfg):
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), compute)
        return specs

    def prefill_batch_specs(self, shape: ShapeSpec, compute=jnp.bfloat16):
        return {k: v for k, v in self.train_batch_specs(shape, compute).items()
                if k != "targets"}

    def decode_state_specs(self, shape: ShapeSpec, dtype=jnp.bfloat16):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        state = jax.eval_shape(
            functools.partial(init_decode_state, cfg, B, S, dtype=dtype))
        return state

    def param_specs(self):
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))


def default_num_blocks(batch: int, max_len: int, block_size: int) -> int:
    """Pool size matching the dense cache's token capacity, plus the
    reserved scratch block (id 0, the garbage sink for free slots)."""
    return batch * (max_len // block_size) + 1


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int, *,
                      dtype=jnp.bfloat16, kv: str = "dense",
                      num_blocks: int | None = None, block_size: int = 16,
                      mesh=None):
    """Concrete zero decode state (also used via eval_shape for specs).

    ``pos`` is a per-row (batch,) vector: every batch row decodes at its own
    absolute position, which is what lets the serving engine refill one slot
    mid-flight (continuous batching) instead of wave-stepping the whole
    block.  Rows that advance in lockstep simply carry equal entries.

    ``kv="paged"`` swaps the dense per-row KV slabs for shared block pools
    plus a per-row ``block_tables`` (batch, max_len // block_size) map; the
    table width times the block size equals ``max_len`` so the gathered
    logical view has the dense shapes (bitwise-equal attend math).

    ``mesh`` places the fresh state per the serve tensor-parallel rules
    (:func:`repro.runtime.sharding.serve_state_shardings`): KV pools shard
    on their head/latent dim over "model", block tables and scalars
    replicate.  Must stay None under ``eval_shape`` (specs carry no
    placement)."""
    if cfg.is_encdec:
        if kv == "paged":
            raise ValueError("paged KV is a decoder-LM path; "
                             f"{cfg.name} is enc-dec (use kv='dense')")
        cache = encdec_mod.init_encdec_cache(cfg, batch, max_len, dtype)
    elif kv == "paged":
        if max_len % block_size:
            raise ValueError(
                f"paged KV needs max_len % block_size == 0, got "
                f"{max_len} % {block_size}")
        nb = num_blocks or default_num_blocks(batch, max_len, block_size)
        cache = tf.init_cache_paged(cfg, batch, max_len, nb, block_size,
                                    dtype)
    else:
        cache = tf.init_cache(cfg, batch, max_len, dtype)
    state = {
        "cache": cache,
        "token": jnp.zeros((batch, 1), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if kv == "paged":
        state["block_tables"] = jnp.zeros(
            (batch, max_len // block_size), jnp.int32)
    if mesh is not None:
        from repro.runtime.sharding import serve_state_shardings
        shardings = serve_state_shardings(state, mesh)
        state = jax.tree.map(jax.device_put, state, shardings)
    return state


def build_model(cfg: ArchConfig, compute=jnp.bfloat16) -> ModelBundle:
    if cfg.is_encdec:
        return _build_encdec(cfg, compute)
    return _build_lm(cfg, compute)


def _build_lm(cfg, compute):
    def init(key):
        return tf.init_lm_params(cfg, key)

    def loss(params, batch):
        return tf.lm_loss(params, cfg, batch["tokens"], batch["targets"],
                          extra_embeds=batch.get("frontend"), compute=compute)

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        S = batch["tokens"].shape[1] + (
            cfg.frontend_tokens if _has_frontend(cfg) else 0)
        cache = tf.init_cache(cfg, B, S, dtype=compute)
        return tf.lm_prefill(params, cfg, batch["tokens"], cache,
                             extra_embeds=batch.get("frontend"),
                             compute=compute)

    def decode(params, state):
        bt = state.get("block_tables")
        logits, cache = tf.lm_decode(params, cfg, state["token"],
                                     state["cache"], state["pos"],
                                     block_tables=bt, compute=compute)
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = {"cache": cache, "token": token, "pos": state["pos"] + 1}
        if bt is not None:
            out["block_tables"] = bt
        return logits, out

    def prefill_chunk(params, state, tokens, table_row, slot, q_offset):
        logits, cache = tf.lm_prefill_chunk(
            params, cfg, tokens, state["cache"], table_row, slot, q_offset,
            compute=compute)
        return logits, {**state, "cache": cache}

    def verify(params, tokens, state):
        logits, cache = tf.lm_verify(params, cfg, tokens, state["cache"],
                                     state["pos"],
                                     block_tables=state.get("block_tables"),
                                     compute=compute)
        return logits, {**state, "cache": cache}

    return ModelBundle(cfg, init, loss, prefill, decode,
                       prefill_chunk=prefill_chunk, verify=verify)


def _build_encdec(cfg, compute):
    def init(key):
        return encdec_mod.init_encdec_params(cfg, key)

    def loss(params, batch):
        return encdec_mod.encdec_loss(params, cfg, batch["frontend"],
                                      batch["tokens"], batch["targets"],
                                      compute=compute)

    def prefill(params, batch):
        B, S = batch["tokens"].shape
        cache = encdec_mod.init_encdec_cache(cfg, B, S, dtype=compute)
        return encdec_mod.encdec_prefill(params, cfg, batch["frontend"],
                                         batch["tokens"], cache,
                                         compute=compute)

    def decode(params, state):
        logits, cache = encdec_mod.encdec_decode(params, cfg, state["token"],
                                                 state["cache"], state["pos"],
                                                 compute=compute)
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return logits, {"cache": cache, "token": token,
                        "pos": state["pos"] + 1}

    return ModelBundle(cfg, init, loss, prefill, decode)
