"""Mixture-of-experts FFN: top-k router + capacity-bucketed dispatch.

Two execution paths:

* ``apply_moe`` (train / prefill): per-example capacity dispatch.  Token
  assignments are bucketed into an (E, C) buffer via a cumsum position
  computation (no sort, no cross-device data movement), experts run as one
  batched einsum, results are combined with router weights.  Overflowing
  tokens are dropped (GShard capacity semantics; capacity_factor=1.25).

* ``apply_moe_dense`` (decode): computes every expert for the single new
  token, weighted by the (zeroed non-top-k) router gates.  Decode is
  memory-bound — all expert weights stream from HBM once either way — so the
  extra FLOPs are roofline-free, and the path has no gather/scatter at all.

Sharding ("tp" partition, the baseline): expert weights are sharded on the
hidden (F) dim over the "model" axis; tokens stay batch-sharded; the down
projection ends in an all-reduce — exactly a dense-TP FFN per expert.
The "ep" partition (experts over "model", token all-to-all) is implemented in
`repro.runtime.ep_moe` via shard_map and used in the §Perf hillclimb.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init
from repro.runtime.sharding import constrain, constrain_replicated


def init_moe(key, cfg):
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (D, E)),
        "up": dense_init(ks[1], (E, D, F), in_axis=1),
        "down": dense_init(ks[2], (E, F, D), in_axis=1),
    }
    if cfg.mlp_gated:
        p["gate"] = dense_init(ks[3], (E, D, F), in_axis=1)
    return p


def _capacity(cfg, seq_len: int) -> int:
    m = cfg.moe
    c = int(seq_len * m.top_k * m.capacity_factor / m.num_experts) + 1
    return min(seq_len, max(8, -(-c // 8) * 8))   # round up to 8, cap at S


def router_probs(x, router_w):
    """f32 router logits -> probs.  x: (..., D)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def _dispatch_one(xe, idx, wts, E: int, C: int):
    """Single example dispatch.  xe: (S,D); idx/wts: (S,k).
    Returns buckets (E,C,D), and (e_flat, pos_flat, keep, wts_flat) for the
    combine step."""
    S, k = idx.shape
    e_flat = idx.reshape(-1)                                   # (S*k,)
    one_hot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)       # (S*k, E)
    pos_flat = (jnp.cumsum(one_hot, axis=0) - one_hot)[jnp.arange(S * k), e_flat]
    keep = pos_flat < C
    pos_c = jnp.where(keep, pos_flat, C - 1)
    tok = jnp.repeat(jnp.arange(S), k)
    contrib = xe[tok] * keep[:, None].astype(xe.dtype)
    buckets = jnp.zeros((E, C, xe.shape[-1]), xe.dtype)
    buckets = buckets.at[e_flat, pos_c].add(contrib, mode="drop")
    return buckets, (e_flat, pos_c, keep, wts.reshape(-1))


def _combine_one(y_buckets, meta, S: int, dtype):
    e_flat, pos_c, keep, wts_flat = meta
    k = e_flat.shape[0] // S
    gathered = y_buckets[e_flat, pos_c]                        # (S*k, D)
    gathered = gathered * (wts_flat * keep).astype(gathered.dtype)[:, None]
    return jnp.sum(gathered.reshape(S, k, -1), axis=1).astype(dtype)


def _bucket_gmm(buckets, w):
    """(B,E,C,D) x (E,D,F) -> (B,E,C,F) via the Pallas grouped matmul.

    Row tiles are laid out (B*E*C, D) with per-tile expert ids, so the kernel
    streams x tiles while hopping expert weight slabs (dense-padded tiling)."""
    from repro.kernels.grouped_matmul.kernel import grouped_matmul_kernel

    B, E, C, D = buckets.shape
    F = w.shape[2]
    bm = 128
    while C % bm:
        bm //= 2
    bn = 128
    while F % bn:
        bn //= 2
    tile_ids = jnp.tile(jnp.repeat(jnp.arange(E), C // bm), B)
    x = buckets.reshape(B * E * C, D)
    y = grouped_matmul_kernel(x, w, tile_ids, block_m=bm, block_n=bn,
                              interpret=jax.default_backend() != "tpu")
    return y.reshape(B, E, C, F)


def apply_moe(x, p, cfg, compute=jnp.bfloat16):
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    C = _capacity(cfg, S)
    probs = router_probs(x, p["router"])                       # (B,S,E) f32
    wts, idx = jax.lax.top_k(probs, k)                         # (B,S,k)
    wts = wts / jnp.maximum(jnp.sum(wts, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2), axis=(0, 1))
    aux = m.router_aux_weight * E * jnp.sum(me * ce / k)

    buckets, meta = jax.vmap(lambda xe, ie, we: _dispatch_one(xe, ie, we, E, C))(
        x, idx, wts)                                           # (B,E,C,D)
    # keep dispatch local: without this XLA may shard the einsum contraction
    # and all-reduce the full bucket tensor (measured 2.7 TB/device on
    # mixtral prefill_32k)
    buckets = constrain(buckets, "b...")

    act = act_fn(cfg.activation)
    if cfg.moe_impl == "gmm":
        up = _bucket_gmm(buckets, p["up"].astype(compute))
        if cfg.mlp_gated:
            g = _bucket_gmm(buckets, p["gate"].astype(compute))
            h = (act(g) * up).astype(compute)
        else:
            h = act(up).astype(compute)
        y = _bucket_gmm(constrain_replicated(h),
                        p["down"].astype(compute)).astype(compute)
    else:
        up = jnp.einsum("becd,edf->becf", buckets, p["up"].astype(compute))
        if cfg.mlp_gated:
            g = jnp.einsum("becd,edf->becf", buckets, p["gate"].astype(compute))
            h = act(g) * up
        else:
            h = act(up)
        h = constrain(h, "b..m")
        h = constrain_replicated(h)
        y = jnp.einsum("becf,efd->becd", h, p["down"].astype(compute))
        y = constrain(y, "b...")

    out = jax.vmap(lambda yb, mt: _combine_one(yb, mt, S, compute))(y, meta)
    return out, aux


def apply_moe_dense(x, p, cfg, compute=jnp.bfloat16):
    """Decode path: all experts on the (B,1,D) token, gated combine."""
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    probs = router_probs(x, p["router"])                       # (B,S,E)
    wts, idx = jax.lax.top_k(probs, k)
    wts = wts / jnp.maximum(jnp.sum(wts, axis=-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs)
    gates = jnp.take_along_axis(gates, idx, axis=-1)           # shape trick
    gates = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None, None],
        jnp.arange(probs.shape[1])[None, :, None],
        idx,
    ].set(wts)                                                 # (B,S,E)

    act = act_fn(cfg.activation)
    up = jnp.einsum("bsd,edf->bsef", x, p["up"].astype(compute))
    if cfg.mlp_gated:
        g = jnp.einsum("bsd,edf->bsef", x, p["gate"].astype(compute))
        h = act(g) * up
    else:
        h = act(up)
    # serve-mode EP: experts over the (otherwise idle) data axis, expert
    # hidden over model — decode weight streaming drops by the data-axis
    # size; token activations are tiny so the reshard is ~free.
    h = constrain(h, "..dm")
    h = constrain_replicated(h)
    y = jnp.einsum("bsef,efd->bsed", h, p["down"].astype(compute))
    out = jnp.einsum("bsed,bse->bsd", y, gates.astype(compute))
    return constrain(out, "b.."), jnp.float32(0.0)
