"""Mamba-2 SSD mixer (state-space duality), chunked for the MXU.

The recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t h_t + D x_t
is evaluated in the SSD chunked form [arXiv:2405.21060]: the sequence is split
into chunks of Q steps; intra-chunk terms become (Q,Q) masked matmuls
(MXU-friendly) and the inter-chunk state (H,P,N per batch) is carried by a
short lax.scan over chunks.  This is the TPU-native adaptation of the
selective-scan: no sequential per-token loop ever touches the fast path.

Decode keeps an O(1) recurrent state: {"conv": (B, W-1, conv_dim),
"ssd": (B, H, P, N)} per layer — the reason SSM archs run `long_500k`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm
from repro.runtime.sharding import constrain


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return s, d_inner, nheads, conv_dim


def init_ssm(key, cfg):
    s, d_inner, nheads, conv_dim = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * s.n_groups * s.state_dim + nheads
    return {
        "in_proj": dense_init(ks[0], (D, in_dim)),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim)) * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)),
        "D_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, nheads))).astype(jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[3], (d_inner, D)),
    }


def _causal_conv(x, w, b):
    """x: (B,S,C); w: (W,C) depthwise causal conv via shifted adds."""
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out + b


def _split_proj(zxbcdt, cfg):
    s, d_inner, nheads, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim :]
    return z, xBC, dt


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: (b,S,H,P)  dt: (b,S,H)  A: (H,)  B,C: (b,S,G,N).  Returns y (b,S,H,P).
    All cumulative/decay math in f32.
    """
    """Returns (y (b,S,H,P), final_state (b,H,N,P))."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    rs = lambda t: t.reshape((b, nc, chunk) + t.shape[2:])
    xc, dtc, Bc, Cc = rs(x), rs(dt.astype(jnp.float32)), rs(B), rs(C)

    dA = dtc * A.astype(jnp.float32)                   # (b,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)                       # inclusive within chunk
    total = cum[:, :, -1]                              # (b,nc,H)

    # ---- intra-chunk: y_t = C_t · sum_{j<=t} exp(cum_t - cum_j) dt_j B_j x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (b,nc,q,j,H)
    q_idx = jnp.arange(chunk)
    causal = q_idx[:, None] >= q_idx[None, :]
    # mask INSIDE the exponent: non-causal seg is positive and can overflow
    # exp() to inf; where(…, exp(seg), 0) would then produce 0*inf = NaN in
    # the backward pass (the where-grad trap).
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)
    CB = jnp.einsum("bcqgn,bcjgn->bcgqj", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                      # (b,nc,G,q,j)
    CB = jnp.repeat(CB, H // G, axis=2)                          # (b,nc,H,q,j)
    M = CB * L.transpose(0, 1, 4, 2, 3)                          # (b,nc,H,q,j)
    xdt = xc.astype(jnp.float32) * dtc[..., None]                # (b,nc,j,H,P)
    y_intra = jnp.einsum("bchqj,bcjhp->bcqhp", M, xdt)

    # ---- chunk-local end states: S_loc = sum_j exp(total - cum_j) dt_j B_j⊗x_j
    assert G == 1, "SSD state einsums assume shared B/C (n_groups=1)"
    decay_out = jnp.exp(total[:, :, None] - cum)                 # (b,nc,j,H)
    # state (b,nc,H,N,P): einsum over j with per-head decay
    S_loc = jnp.einsum("bcjgn,bcjh,bcjhp->bchnp",
                       Bc.astype(jnp.float32), decay_out * dtc,
                       xc.astype(jnp.float32))

    # ---- inter-chunk recurrence (scan over chunks)
    def body(s_prev, inp):
        s_loc_c, total_c = inp                                   # (b,H,N,P),(b,H)
        s_new = jnp.exp(total_c)[:, :, None, None] * s_prev + s_loc_c
        return s_new, s_prev

    s0 = jnp.zeros((b, H, B.shape[-1], P), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        body, s0,
        (S_loc.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                   # (b,nc,H,N,P)

    # ---- inter-chunk contribution: y_t += C_t · exp(cum_t) S_prev
    decay_in = jnp.exp(cum)                                      # (b,nc,q,H)
    y_inter = jnp.einsum("bcqgn,bcqh,bchnp->bcqhp",
                         Cc.astype(jnp.float32), decay_in, s_prevs)

    y = (y_intra + y_inter).reshape(b, Sp, H, P)
    return (y[:, :S] if pad else y), s_final


def _ssm_forward_impl(x, p, cfg, compute, want_cache: bool):
    s, d_inner, nheads, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(compute))
    z, xBC_pre, dt = _split_proj(zxbcdt, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC_pre, p["conv_w"].astype(compute),
                                   p["conv_b"].astype(compute)))
    xs = xBC[..., :d_inner]
    B_ssm = xBC[..., d_inner : d_inner + s.n_groups * s.state_dim]
    C_ssm = xBC[..., d_inner + s.n_groups * s.state_dim :]
    b, S, _ = x.shape
    xh = constrain(xs.reshape(b, S, nheads, s.head_dim), "b.m.")
    Bh = B_ssm.reshape(b, S, s.n_groups, s.state_dim)
    Ch = C_ssm.reshape(b, S, s.n_groups, s.state_dim)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if cfg.ssm_impl == "pallas":
        from repro.kernels.ssd_scan.ops import ssd_scan
        y, s_final = ssd_scan(xh, dt_sp, A, Bh, Ch, chunk=s.chunk_size)
        y = y.astype(jnp.float32)
    else:
        y, s_final = ssd_chunked(xh, dt_sp, A, Bh, Ch, s.chunk_size)
    y = y + xh.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(b, S, d_inner).astype(compute)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(compute))
    if not want_cache:
        return out, None
    W = s.conv_width
    tail = xBC_pre[:, -(W - 1):] if S >= W - 1 else jnp.pad(
        xBC_pre, ((0, 0), (W - 1 - S, 0), (0, 0)))
    cache = {"conv": tail.astype(jnp.bfloat16),
             # ssd_chunked carries state as (b,H,N,P); decode uses (b,H,N,P)
             "ssd": s_final}
    return out, cache


def ssm_forward(x, p, cfg, compute=jnp.bfloat16):
    """Full Mamba-2 block over a sequence.  x: (B,S,D) -> (B,S,D)."""
    return _ssm_forward_impl(x, p, cfg, compute, want_cache=False)[0]


def ssm_forward_with_cache(x, p, cfg, compute=jnp.bfloat16):
    """Prefill: (out, decode cache {conv, ssd})."""
    return _ssm_forward_impl(x, p, cfg, compute, want_cache=True)


# --------------------------------------------------------------------------
# Decode (O(1) state)
# --------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16):
    s, d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, nheads, s.state_dim, s.head_dim), jnp.float32),
    }


def ssm_prefill_chunk_row(x, p, cfg, cache, slot, compute=jnp.bfloat16):
    """Chunked-prefill step for ONE batch row of an SSM layer: scan the
    chunk's tokens through `ssm_decode` starting from row `slot`'s cached
    state (zeroed by the engine before the first chunk), then write the
    row state back.  x: (1,C,D); cache: full-batch {conv, ssd}.
    Returns (out (1,C,D), new_cache)."""
    row = jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, slot, 0, True), cache)

    def body(c, xt):
        out, c2 = ssm_decode(xt[None, None, :], p, cfg, c, compute=compute)
        return c2, out[0, 0]

    row_new, outs = jax.lax.scan(body, row, x[0])
    new_cache = jax.tree.map(
        lambda full, r: jax.lax.dynamic_update_slice_in_dim(full, r, slot, 0),
        cache, row_new)
    return outs[None], new_cache


def ssm_decode(x, p, cfg, cache, compute=jnp.bfloat16):
    """One token.  x: (B,1,D) -> (out (B,1,D), new cache)."""
    s, d_inner, nheads, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(compute))
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC = xBC[:, 0]                                              # (B,conv_dim)
    # conv over (cached W-1 inputs + current)
    hist = jnp.concatenate([cache["conv"].astype(compute), xBC[:, None]], axis=1)
    w = p["conv_w"].astype(compute)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(compute)
    xBC_t = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:].astype(cache["conv"].dtype)

    xs = xBC_t[..., :d_inner]
    B_t = xBC_t[..., d_inner : d_inner + s.n_groups * s.state_dim]
    C_t = xBC_t[..., d_inner + s.n_groups * s.state_dim :]
    b = x.shape[0]
    xh = xs.reshape(b, nheads, s.head_dim).astype(jnp.float32)
    Bh = B_t.reshape(b, s.n_groups, s.state_dim).astype(jnp.float32)
    Ch = C_t.reshape(b, s.n_groups, s.state_dim).astype(jnp.float32)
    Bh = jnp.repeat(Bh, nheads // s.n_groups, axis=1)            # (B,H,N)
    Ch = jnp.repeat(Ch, nheads // s.n_groups, axis=1)
    dt_sp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_sp * A)                                   # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt_sp, Bh, xh)
    state = decay[:, :, None, None] * cache["ssd"] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(compute)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(compute))
    return out, {"conv": new_conv, "ssd": state}
