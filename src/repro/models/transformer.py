"""Unified decoder-only LM covering dense / MoE / SSM / hybrid / VLM archs.

Layers are organized into *groups* of ``period`` layers, where
``period = lcm(attn_period, moe_period)`` (1 for uniform stacks, 8 for
jamba's 1:7 mamba:attn interleave with alternating MoE).  Parameters are
stacked per slot across groups and the group is the body of a
``jax.lax.scan`` — compile time and HLO size stay O(period), not O(L),
which keeps the 80-cell dry-run tractable and is how the framework holds
compile latency down in production (late-binding's "image pull" cost).

Caches (decode) are pytrees stacked the same way and threaded through the
scan as per-iteration xs/ys.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp, apply_norm, embed_init, embed_lookup, init_mlp, init_norm,
    lm_logits, rope_table, softmax_cross_entropy_fused,
)
from repro.runtime.sharding import constrain


# --------------------------------------------------------------------------
# Layer-slot layout
# --------------------------------------------------------------------------

def group_period(cfg) -> int:
    p = 1
    if cfg.ssm is not None and not cfg.is_attention_free:
        p = math.lcm(p, cfg.attn_period)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe_period)
    return p


def layer_slots(cfg) -> list[dict]:
    """Static per-slot structure within one group."""
    period = group_period(cfg)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    attn_set = set(i % period for i in cfg.attn_layer_indices() if i < period)
    moe_set = set(i % period for i in cfg.moe_layer_indices() if i < period)
    slots = []
    for i in range(period):
        if cfg.is_attention_free:
            mixer = "ssm"
        else:
            mixer = "attn" if (cfg.ssm is None or i in attn_set) else "ssm"
        if cfg.moe is not None and i in moe_set:
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        slots.append({"mixer": mixer, "ffn": ffn})
    return slots


def _rope_for(cfg, S):
    if cfg.is_attention_free:
        return (None, None)
    dim = cfg.mla.qk_rope_head_dim if cfg.mla is not None else cfg.head_dim
    return rope_table(jnp.arange(S), dim, cfg.rope_theta)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_slot(key, cfg, slot):
    ks = jax.random.split(key, 4)
    p = {"mixer_norm": init_norm(ks[0], cfg)}
    if slot["mixer"] == "attn":
        p["mixer"] = attn.init_attention(ks[1], cfg)
    else:
        p["mixer"] = ssm_mod.init_ssm(ks[1], cfg)
    if slot["ffn"] != "none":
        p["ffn_norm"] = init_norm(ks[2], cfg)
        p["ffn"] = (init_mlp(ks[3], cfg) if slot["ffn"] == "dense"
                    else moe_mod.init_moe(ks[3], cfg))
    return p


def init_lm_params(cfg, key):
    """Full parameter pytree; layer leaves have leading dim n_groups."""
    period = group_period(cfg)
    n_groups = cfg.num_layers // period
    slots = layer_slots(cfg)
    k_embed, k_head, k_norm, k_layers = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, (n_groups, period))

    def init_group(gkeys):
        return [_init_slot(gkeys[i], cfg, slots[i]) for i in range(period)]

    layers = jax.vmap(init_group)(layer_keys)
    params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model)),
        "layers": layers,
        "final_norm": init_norm(k_norm, cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(k_head, (cfg.d_model, cfg.vocab_size))
    return params


# --------------------------------------------------------------------------
# Forward (train)
# --------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _apply_slot(x, p, cfg, slot, rope, compute):
    aux = jnp.float32(0.0)
    h = apply_norm(x, p["mixer_norm"], cfg)
    if slot["mixer"] == "attn":
        h = attn.attention_forward(
            h, p["mixer"], cfg, rope_cos=rope[0], rope_sin=rope[1],
            causal=True, window=cfg.sliding_window, compute=compute)
    else:
        h = ssm_mod.ssm_forward(h, p["mixer"], cfg, compute=compute)
    x = x + h
    if slot["ffn"] != "none":
        h = apply_norm(x, p["ffn_norm"], cfg)
        if slot["ffn"] == "dense":
            h = apply_mlp(h, p["ffn"], cfg, compute)
        else:
            h, aux = moe_mod.apply_moe(h, p["ffn"], cfg, compute)
        x = x + h
    return x, aux


def lm_backbone(params, cfg, x, *, compute=jnp.bfloat16):
    """Run the layer stack over embeddings x: (B,S,D) -> (hidden, aux_loss)."""
    slots = layer_slots(cfg)
    rope = _rope_for(cfg, x.shape[1])

    def group_body(carry, gparams):
        x, aux = carry
        # re-pin the batch sharding: XLA drops it in the grad(remat(scan))
        # backward loop otherwise (see runtime.sharding.constrain)
        x = constrain(x, "b..")
        for i, slot in enumerate(slots):
            x, a = _apply_slot(x, gparams[i], cfg, slot, rope, compute)
            aux = aux + a
        x = constrain(x, "b..")
        return (x, aux), None

    body = _remat(group_body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    x = apply_norm(x, params["final_norm"], cfg)
    return x, aux


def head_matrix(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def lm_loss(params, cfg, tokens, targets, *, extra_embeds=None,
            loss_mask=None, compute=jnp.bfloat16):
    """Next-token CE loss.  extra_embeds (B,F,D) are prepended (VLM/audio
    stub frontends); the loss covers token positions only."""
    x = embed_lookup(tokens, params["embed"], compute)
    n_extra = 0
    if extra_embeds is not None:
        n_extra = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(compute), x], axis=1)
    h, aux = lm_backbone(params, cfg, x, compute=compute)
    h = h[:, n_extra:]
    ce = softmax_cross_entropy_fused(
        h, head_matrix(params, cfg), targets,
        softcap=cfg.logit_softcap, mask=loss_mask, chunk=cfg.loss_chunk)
    return ce + aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# Prefill / decode with caches
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked per-group cache pytree: list per slot, leaves (n_groups, ...)."""
    period = group_period(cfg)
    n_groups = cfg.num_layers // period
    slots = layer_slots(cfg)

    def one(slot):
        if slot["mixer"] == "attn":
            c = attn.init_kv_cache(cfg, batch, max_len, dtype)
        else:
            c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), c)

    return [one(s) for s in slots]


def init_cache_paged(cfg, batch: int, max_len: int, num_blocks: int,
                     block_size: int, dtype=jnp.bfloat16):
    """Paged variant of `init_cache`: attention layers allocate a shared
    block pool (n_groups, num_blocks, block_size, ...) instead of a dense
    (n_groups, batch, max_len, ...) slab; SSM state and SWA rings stay
    per-row (they are O(1) / always-live respectively)."""
    period = group_period(cfg)
    n_groups = cfg.num_layers // period
    slots = layer_slots(cfg)

    def one(slot):
        if slot["mixer"] == "attn":
            c = attn.init_kv_cache_paged(cfg, batch, max_len, num_blocks,
                                         block_size, dtype)
        else:
            c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), c)

    return [one(s) for s in slots]


def _slot_prefill(x, p, cfg, slot, rope, old_cache, compute):
    """One layer over the full sequence, also producing its decode cache."""
    h = apply_norm(x, p["mixer_norm"], cfg)
    if slot["mixer"] == "attn":
        out, nc = attn.attention_prefill(
            h, p["mixer"], cfg, rope, old_cache,
            window=cfg.sliding_window, compute=compute)
    else:
        out, nc = ssm_mod.ssm_forward_with_cache(h, p["mixer"], cfg,
                                                 compute=compute)
    x = x + out
    if slot["ffn"] != "none":
        h = apply_norm(x, p["ffn_norm"], cfg)
        if slot["ffn"] == "dense":
            h = apply_mlp(h, p["ffn"], cfg, compute)
        else:
            h, _ = moe_mod.apply_moe(h, p["ffn"], cfg, compute)
        x = x + h
    return x, nc


def lm_prefill(params, cfg, tokens, cache, *, extra_embeds=None,
               compute=jnp.bfloat16):
    """Full-sequence prefill: returns (last-position logits, filled cache)."""
    slots = layer_slots(cfg)
    x = embed_lookup(tokens, params["embed"], compute)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(compute), x], axis=1)
    rope = _rope_for(cfg, x.shape[1])

    def group_body(x, inp):
        gparams, gcache = inp
        x = constrain(x, "b..")
        new_gcache = []
        for i, slot in enumerate(slots):
            x, nc = _slot_prefill(x, gparams[i], cfg, slot, rope, gcache[i],
                                  compute)
            new_gcache.append(nc)
        return x, new_gcache

    x, new_cache = jax.lax.scan(_remat(group_body, cfg), x,
                                (params["layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(x[:, -1:], head_matrix(params, cfg), cfg.logit_softcap)
    return logits, new_cache


def lm_decode(params, cfg, token, cache, pos, *, block_tables=None,
              compute=jnp.bfloat16):
    """One decode step.  token: (B,1) int32; pos: scalar or (B,) int32
    absolute position(s) of the new token — per-row positions are the
    continuous-batching serve path.  ``block_tables`` (B, mb) routes paged
    cache leaves; one table serves every layer (all pools share physical
    block ids).  Returns (logits (B,1,V), new cache)."""
    slots = layer_slots(cfg)
    x = embed_lookup(token, params["embed"], compute)

    def group_body(x, inp):
        gparams, gcache = inp
        x = constrain(x, "b..")
        new_gcache = []
        for i, slot in enumerate(slots):
            p = gparams[i]
            h = apply_norm(x, p["mixer_norm"], cfg)
            if slot["mixer"] == "attn":
                h, nc = attn.attention_decode(
                    h, p["mixer"], cfg, gcache[i], pos,
                    window=cfg.sliding_window, block_tables=block_tables,
                    compute=compute)
            else:
                h, nc = ssm_mod.ssm_decode(h, p["mixer"], cfg, gcache[i],
                                           compute=compute)
            new_gcache.append(nc)
            x = x + h
            if slot["ffn"] != "none":
                h = apply_norm(x, p["ffn_norm"], cfg)
                if slot["ffn"] == "dense":
                    h = apply_mlp(h, p["ffn"], cfg, compute)
                else:
                    h, _ = moe_mod.apply_moe_dense(h, p["ffn"], cfg, compute)
                x = x + h
        return x, new_gcache

    x, new_cache = jax.lax.scan(group_body, x, (params["layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(x, head_matrix(params, cfg), cfg.logit_softcap)
    return logits, new_cache


def lm_verify(params, cfg, tokens, cache, pos, *, block_tables=None,
              compute=jnp.bfloat16):
    """Speculative-verify forward: score S = k+1 consecutive positions of
    every row in ONE pass.  tokens: (B,S) int32 — ``tokens[:,0]`` is the
    pending token at ``pos`` and ``tokens[:,1:]`` the draft proposals;
    pos: (B,) absolute position of tokens[:,0].  Structurally `lm_decode`
    with an S-wide token axis: every position-wise op (embed, norms, MLP,
    dense-MoE, logits) batches over S, while the attention mixer loops the
    S queries through the exact single-token attend — which is what keeps
    each position's logits bitwise-equal to the sequential decode steps it
    replaces.  Paged attention-only archs: SSM mixers have no multi-token
    state-rollback path (the engine falls back to spec="off" for them).
    Returns (logits (B,S,V), new cache)."""
    slots = layer_slots(cfg)
    x = embed_lookup(tokens, params["embed"], compute)

    def group_body(x, inp):
        gparams, gcache = inp
        x = constrain(x, "b..")
        new_gcache = []
        for i, slot in enumerate(slots):
            if slot["mixer"] != "attn":
                raise ValueError(
                    f"{cfg.name}: speculative verify needs every mixer to "
                    "be paged attention; SSM state rows advance one token "
                    "at a time and cannot roll back a rejected suffix")
            p = gparams[i]
            h = apply_norm(x, p["mixer_norm"], cfg)
            h, nc = attn.attention_verify(
                h, p["mixer"], cfg, gcache[i], pos,
                block_tables=block_tables, compute=compute)
            new_gcache.append(nc)
            x = x + h
            if slot["ffn"] != "none":
                h = apply_norm(x, p["ffn_norm"], cfg)
                if slot["ffn"] == "dense":
                    h = apply_mlp(h, p["ffn"], cfg, compute)
                else:
                    h, _ = moe_mod.apply_moe_dense(h, p["ffn"], cfg, compute)
                x = x + h
        return x, new_gcache

    x, new_cache = jax.lax.scan(group_body, x, (params["layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(x, head_matrix(params, cfg), cfg.logit_softcap)
    return logits, new_cache


def lm_prefill_chunk(params, cfg, tokens, cache, table_row, slot,
                     q_offset, *, compute=jnp.bfloat16):
    """One CHUNK of an admission prefill, into ONE batch row of the shared
    (paged) decode cache.  tokens: (1,C) int32; table_row: (mb,) int32 the
    admitted row's physical block ids; slot: scalar int32 batch row;
    q_offset: scalar int32 absolute position of tokens[:,0].  Only row
    `slot`'s state (its blocks / ring row / ssm row) is written — the
    other rows keep decoding bit-identically in between chunks.  Returns
    (last-position logits (1,V), new cache)."""
    slots = layer_slots(cfg)
    x = embed_lookup(tokens, params["embed"], compute)

    def group_body(x, inp):
        gparams, gcache = inp
        x = constrain(x, "b..")
        new_gcache = []
        for i, slot_s in enumerate(slots):
            p = gparams[i]
            h = apply_norm(x, p["mixer_norm"], cfg)
            if slot_s["mixer"] == "attn":
                h, nc = attn.attention_prefill_chunk(
                    h, p["mixer"], cfg, gcache[i], table_row, slot,
                    q_offset, window=cfg.sliding_window, compute=compute)
            else:
                h, nc = ssm_mod.ssm_prefill_chunk_row(
                    h, p["mixer"], cfg, gcache[i], slot, compute=compute)
            new_gcache.append(nc)
            x = x + h
            if slot_s["ffn"] != "none":
                h = apply_norm(x, p["ffn_norm"], cfg)
                if slot_s["ffn"] == "dense":
                    h = apply_mlp(h, p["ffn"], cfg, compute)
                else:
                    h, _ = moe_mod.apply_moe_dense(h, p["ffn"], cfg, compute)
                x = x + h
        return x, new_gcache

    x, new_cache = jax.lax.scan(group_body, x, (params["layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(x[:, -1:], head_matrix(params, cfg), cfg.logit_softcap)
    return logits[:, 0], new_cache
