"""Attention: GQA/MQA, sliding-window, MLA; train/prefill and decode paths.

Three implementations of the core attend step (selected by cfg.attn_impl):

* ``chunked``  — pure-JAX flash-style online softmax, lax.scan over KV chunks.
  Memory O(S·d + chunk) instead of O(S²); FLOPs equal to full attention
  (every (q,kv) chunk pair is computed, masked ones included).  This is the
  paper-faithful baseline path used by the dry-run.
* ``causal_blocked`` — beyond-paper compute optimization: static triangular
  iteration over (q-block, kv-block) pairs skips fully-masked kv blocks,
  halving causal-attention FLOPs (and bounding SWA to O(S·window)).
* ``pallas`` — TPU Pallas kernel (repro.kernels.flash_attention); validated
  in interpret mode on CPU, used on real TPU hardware.

Decode attends a single new token against a KV cache.  For ``long_500k``
(batch=1) the cache sequence dim is sharded over the "model" axis and the
softmax reductions become XLA-SPMD all-reduces — exactly flash-decode
split-K, derived by the partitioner instead of hand-written NCCL.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense_init, rmsnorm, rope_table
from repro.runtime.sharding import constrain, constrain_replicated

NEG_INF = -1e30


# ==========================================================================
# Parameter init
# ==========================================================================

def init_attention(key, cfg):
    if cfg.mla is not None:
        return _init_mla(key, cfg)
    D, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H, Dh)),
        "wk": dense_init(ks[1], (D, K, Dh)),
        "wv": dense_init(ks[2], (D, K, Dh)),
        "wo": dense_init(ks[3], (H, Dh, D), in_axis=0),
    }


def _init_mla(key, cfg):
    s = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (D, s.q_lora_rank)),
        "q_norm": jnp.zeros((s.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], (s.q_lora_rank, H, s.qk_head_dim)),
        "wkv_a": dense_init(ks[2], (D, s.kv_lora_rank + s.qk_rope_head_dim)),
        "kv_norm": jnp.zeros((s.kv_lora_rank,), jnp.float32),
        "wkv_b": dense_init(ks[3], (s.kv_lora_rank, H, s.qk_nope_head_dim + s.v_head_dim)),
        "wo": dense_init(ks[4], (H, s.v_head_dim, D), in_axis=0),
    }


# ==========================================================================
# Core attend: (q, k, v) -> out, several implementations
# ==========================================================================

def _gqa_shapes(q, k):
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    return B, S, H, K, G, Dh


def _mask_chunk(q_pos, t_pos, causal, window):
    """(S, Ck) boolean validity mask."""
    m = jnp.ones((q_pos.shape[0], t_pos.shape[0]), bool)
    if causal:
        m &= t_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= t_pos[None, :] > (q_pos[:, None] - window)
    return m


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      chunk=1024):
    """Flash-style online-softmax attention, scanning KV chunks.

    q: (B,S,H,Dh); k,v: (B,T,K,Dh).  q_offset: absolute position of q[0]
    (prefill continuation / blocked iteration).  Returns (B,S,H,Dh).
    """
    B, S, H, K, G, Dh = _gqa_shapes(q, k)
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    scale = 1.0 / np.sqrt(Dh)

    qg = q.reshape(B, S, K, G, Dh).astype(jnp.bfloat16)
    kc = k.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        t_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        valid = _mask_chunk(q_pos, t_pos, causal, window)
        valid &= t_pos[None, :] < T            # padding
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(jnp.bfloat16),
                        vb.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    init = (
        jnp.full((B, K, G, S), NEG_INF, jnp.float32),
        jnp.zeros((B, K, G, S), jnp.float32),
        jnp.zeros((B, K, G, S, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def causal_blocked_attention(q, k, v, *, window=None, chunk=1024,
                             block_q=2048):
    """Triangular block iteration: q blocks are a static python loop, each
    attending only to its causal (and windowed) KV prefix.  Skips ~half the
    FLOPs of `chunked_attention` for causal masks; O(S·window) for SWA."""
    B, S, H, K, G, Dh = _gqa_shapes(q, k)
    T = k.shape[1]
    assert S == T, "blocked path is for self-attention (train/prefill)"
    block_q = min(block_q, S)
    if S % block_q:
        return chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    outs = []
    for i in range(S // block_q):
        q_lo, q_hi = i * block_q, (i + 1) * block_q
        kv_lo = 0
        if window is not None:
            kv_lo = max(0, (q_lo - window + 1) // chunk * chunk)
        kv_hi = q_hi
        qb = q[:, q_lo:q_hi]
        kb = k[:, kv_lo:kv_hi]
        vb = v[:, kv_lo:kv_hi]
        # positions inside the block are q_lo..q_hi-1; kv starts at kv_lo.
        # chunked_attention masks with absolute positions via q_offset.
        outs.append(
            _chunked_attention_abs(qb, kb, vb, q_offset=q_lo, kv_offset=kv_lo,
                                   window=window, chunk=chunk))
    return jnp.concatenate(outs, axis=1)


def _chunked_attention_abs(q, k, v, *, q_offset, kv_offset, window, chunk):
    """chunked_attention with an absolute kv offset (for blocked iteration)."""
    B, S, H, K, G, Dh = _gqa_shapes(q, k)
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, S, K, G, Dh).astype(jnp.bfloat16)
    kc = k.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        t_pos = kv_offset + idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        valid = _mask_chunk(q_pos, t_pos, True, window)
        valid &= t_pos[None, :] < kv_offset + T
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(jnp.bfloat16),
                        vb.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    init = (
        jnp.full((B, K, G, S), NEG_INF, jnp.float32),
        jnp.zeros((B, K, G, S), jnp.float32),
        jnp.zeros((B, K, G, S, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def attend(q, k, v, cfg, *, causal=True, window=None, q_offset=0):
    """Dispatch on cfg.attn_impl (self-attention, train/prefill)."""
    if cfg.attn_impl == "causal_blocked" and causal:
        return causal_blocked_attention(q, k, v, window=window,
                                        chunk=cfg.attn_chunk)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, chunk=cfg.attn_chunk)


def decode_attend(q, k_cache, v_cache, cache_len, *, window=None):
    """Single-token attention against a KV cache.

    q: (B,1,H,Dh); caches: (B,T,K,Dh); cache_len: scalar or (B,) count of
    valid entries per row (continuous batching gives every batch row its own
    position, so the lengths are ragged).  With T sharded over "model", the
    max/sum reductions lower to all-reduces = flash-decode split-K via SPMD.
    """
    B, _, H, Dh = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, K, G, Dh).astype(jnp.bfloat16)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) * scale
    t_pos = jnp.arange(T)
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    valid = t_pos[None, :] < cl[:, None]                      # (B,T) ragged
    # Rolling SWA caches keep only the last `window` tokens, so every valid
    # slot is inside the window by construction; no extra masking needed.
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# ==========================================================================
# Full layer forward (projection + rope + attend + out-proj)
# ==========================================================================

def attention_forward(x, p, cfg, *, rope_cos, rope_sin, causal=True,
                      window=None, kv=None, compute=jnp.bfloat16):
    """Self- (kv=None) or cross- (kv=(k_in,)) attention over a full sequence.

    x: (B,S,D).  rope tables match S (None for cross-attention).
    """
    if cfg.mla is not None:
        return _mla_forward(x, p, cfg, rope_cos=rope_cos, rope_sin=rope_sin,
                            compute=compute)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute)),
                  "b.m.")
    src = x if kv is None else kv
    k = constrain(jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(compute)),
                  "b.m.")
    v = constrain(jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(compute)),
                  "b.m.")
    if rope_cos is not None:
        q = apply_rope(q, rope_cos, rope_sin)
        k = apply_rope(k, rope_cos, rope_sin)
    out = constrain(attend(q, k, v, cfg, causal=causal, window=window),
                    "b.m.")
    return jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))


def _ring_write_full(k, v, cache, window=None):
    """Write a full prefill's k/v (B,S,K,Dh) into a (possibly rolling) cache
    (B,T,K,Dh), aligned so that slot = pos mod T."""
    S = k.shape[1]
    T = cache["k"].shape[1]
    if S <= T:
        kk = jnp.pad(k, ((0, 0), (0, T - S), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, T - S), (0, 0), (0, 0)))
        return {"k": kk.astype(cache["k"].dtype), "v": vv.astype(cache["v"].dtype)}
    # keep the latest occupant of each ring slot: pos = S-1 - ((S-1-slot) mod T)
    slot_ids = jnp.arange(T)
    pos = (S - 1) - jnp.mod((S - 1) - slot_ids, T)
    kk = jnp.take(k, pos, axis=1).astype(cache["k"].dtype)
    vv = jnp.take(v, pos, axis=1).astype(cache["v"].dtype)
    return {"k": kk, "v": vv}


def attention_prefill(x, p, cfg, rope, cache, *, window=None,
                      compute=jnp.bfloat16):
    """Full-sequence self-attention that also fills the decode cache.

    Returns (out (B,S,D), new_cache)."""
    if cfg.mla is not None:
        return _mla_prefill(x, p, cfg, rope, cache, compute=compute)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute)),
                  "b.m.")
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute)),
                  "b.m.")
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute)),
                  "b.m.")
    if rope[0] is not None:
        q = apply_rope(q, rope[0], rope[1])
        k = apply_rope(k, rope[0], rope[1])
    out = constrain(attend(q, k, v, cfg, causal=True, window=window), "b.m.")
    out = jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))
    return out, _ring_write_full(k, v, cache, window)


def _mla_prefill(x, p, cfg, rope, cache, *, compute):
    """MLA prefill: full-expansion attention + compressed-latent cache fill."""
    s = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)
    q_rope = apply_rope(q_rope, rope[0], rope[1])
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv = rmsnorm(kv_a[..., : s.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[:, :, None, s.kv_lora_rank:], rope[0], rope[1])
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].astype(compute))
    k_nope = kv[..., : s.qk_nope_head_dim]
    v = kv[..., s.qk_nope_head_dim:]
    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "b.m.")
    k = constrain(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, s.qk_rope_head_dim))],
        axis=-1), "b.m.")
    v_pad = constrain(jnp.pad(
        v, ((0, 0), (0, 0), (0, 0), (0, s.qk_head_dim - s.v_head_dim))),
        "b.m.")
    out = constrain(attend(q, k, v_pad, cfg, causal=True), "b.m.")
    out = out[..., : s.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))
    T = cache["ckv"].shape[1]
    ckv_w = jnp.pad(ckv, ((0, 0), (0, T - S), (0, 0))) if S <= T else ckv[:, -T:]
    kr = k_rope[:, :, 0]
    kr_w = jnp.pad(kr, ((0, 0), (0, T - S), (0, 0))) if S <= T else kr[:, -T:]
    return out, {"ckv": ckv_w.astype(cache["ckv"].dtype),
                 "krope": kr_w.astype(cache["krope"].dtype)}


def _row_positions(pos, batch: int):
    """Normalize a decode position to the per-row (B,) form.  Scalar `pos`
    (every row at the same absolute position — the wave-era contract) is
    broadcast; a (B,) vector (continuous batching) passes through."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


# --------------------------------------------------------------------------
# Paged KV: block-pool writes and gathers
# --------------------------------------------------------------------------
#
# The paged cache is a shared pool ``(num_blocks, block_size, ...)`` plus a
# per-row block table ``(B, max_blocks)``: logical position ``p`` of row
# ``b`` lives at ``pool[table[b, p // bs], p % bs]``.  Block 0 is a
# reserved scratch block — free slots keep decoding over garbage (cheaper
# than masking the batched matmuls, same as the dense engine) and their
# writes land there, never in a live request's blocks.  The XLA fallback
# gathers each row's logical ``(max_blocks * bs,)`` view, which the
# allocator sizes to the engine ``max_len`` so the attend math (shapes,
# masks, reduction order) is bitwise-identical to the dense ring path.


def _paged_write_rows(pool, new, block_tables, pos):
    """Per-row paged write: pool (nb, bs, ...), new (B, 1, ...),
    block_tables (B, mb), pos (B,).  Row b's new entry lands at
    ``pool[table[b, (pos_b // bs) % mb], pos_b % bs]``."""
    bs = pool.shape[1]
    mb = block_tables.shape[1]
    pb = jnp.take_along_axis(
        block_tables, ((pos // bs) % mb)[:, None], axis=1)[:, 0]
    return pool.at[pb, pos % bs].set(new[:, 0].astype(pool.dtype))


def _paged_gather(pool, block_tables):
    """Materialize each row's logical view: (B, mb * bs, ...).  XLA
    fallback only — the Pallas kernel gathers via scalar prefetch.  One
    definition shared with the kernel oracle so the fallback and the
    oracle can never diverge."""
    from repro.kernels.paged_attention.ref import gather_kv
    return gather_kv(pool, block_tables)


def _paged_write_chunk(pool, new, table_row, positions):
    """Write a prefill chunk's rows for ONE batch row: pool (nb, bs, ...),
    new (C, ...), table_row (mb,), positions (C,) absolute."""
    bs = pool.shape[1]
    mb = table_row.shape[0]
    pb = table_row[(positions // bs) % mb]
    return pool.at[pb, positions % bs].set(new.astype(pool.dtype))


def _ring_write_rows(cache, new, slot):
    """Per-row ring-buffer write: cache (B,T,...), new (B,1,...), slot (B,).
    Each batch row lands at its own `pos mod T` — the vectorized form of the
    old scalar dynamic_update_slice."""
    upd = jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0))
    return upd(cache, new.astype(cache.dtype), slot)


def attention_decode(x, p, cfg, cache, pos, *, rope_theta=None,
                     window=None, block_tables=None, compute=jnp.bfloat16):
    """One decode step.  x: (B,1,D); cache {"k","v"}: (B,T,K,Dh) dense ring
    or {"kp","vp"}: (nb,bs,K,Dh) paged pool (then ``block_tables`` (B,mb)
    maps rows to blocks); pos: scalar or (B,) absolute position(s) of the
    new token — per-row positions are the continuous-batching path.
    Returns (out, new_cache)."""
    if cfg.mla is not None:
        return _mla_decode(x, p, cfg, cache, pos, block_tables=block_tables,
                           compute=compute)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    B = x.shape[0]
    pos = _row_positions(pos, B)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute))
    cos, sin = rope_table(pos[:, None], cfg.head_dim, theta)   # (B,1,dim/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if "kp" in cache:                       # paged block pool
        k_pool = _paged_write_rows(cache["kp"], k, block_tables, pos)
        v_pool = _paged_write_rows(cache["vp"], v, block_tables, pos)
        T = block_tables.shape[1] * k_pool.shape[1]
        cache_len = jnp.minimum(pos + 1, T)
        if cfg.attn_impl == "pallas":
            from repro.kernels.paged_attention.ops import (
                paged_decode_attention, paged_decode_attention_tp, tp_heads)
            from repro.runtime.sharding import active_mesh
            mesh = active_mesh()
            if tp_heads(mesh, cfg.num_kv_heads, cfg.num_heads):
                out = paged_decode_attention_tp(q[:, 0], k_pool, v_pool,
                                                block_tables, cache_len,
                                                mesh)[:, None]
            else:
                out = paged_decode_attention(q[:, 0], k_pool, v_pool,
                                             block_tables, cache_len)[:, None]
        else:
            out = decode_attend(q, _paged_gather(k_pool, block_tables),
                                _paged_gather(v_pool, block_tables),
                                cache_len, window=window)
        out = jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))
        return out, {"kp": k_pool, "vp": v_pool}
    T = cache["k"].shape[1]
    # per-row ring-buffer write (rolling for SWA; plain append when T >= max)
    slot = jnp.mod(pos, T)
    k_cache = _ring_write_rows(cache["k"], k, slot)
    v_cache = _ring_write_rows(cache["v"], v, slot)
    cache_len = jnp.minimum(pos + 1, T)
    if cfg.attn_impl == "pallas":
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q[:, 0], k_cache, v_cache,
                               cache_len)[:, None]
    else:
        out = decode_attend(q, k_cache, v_cache, cache_len, window=window)
    out = jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))
    return out, {"k": k_cache, "v": v_cache}


def _paged_write_seq(pool, new, block_tables, pos):
    """Multi-position paged write for speculative verify: pool (nb, bs, ...),
    new (B, S, ...), block_tables (B, mb), pos (B,) base positions.  Row
    ``b``'s entry ``s`` lands at logical position ``pos_b + s``.  Unlike
    `_paged_write_rows` (which wraps the table index — safe for single-step
    decode because eviction fires before the wrap is reachable), positions
    at or past the table's logical capacity ``mb*bs`` are routed to the
    reserved scratch block 0 EXPLICITLY: a verify burst can run up to k
    positions past a row's end before acceptance clamps it, and those
    overflow writes must never land in a live (or prefix-shared) block."""
    bs = pool.shape[1]
    mb = block_tables.shape[1]
    S = new.shape[1]
    positions = pos[:, None] + jnp.arange(S)[None]             # (B, S)
    inb = positions < mb * bs
    blk = jnp.where(inb, positions // bs, 0)
    pb = jnp.where(inb, jnp.take_along_axis(block_tables, blk, axis=1), 0)
    return pool.at[pb, positions % bs].set(new.astype(pool.dtype))


def attention_verify(x, p, cfg, cache, pos, *, block_tables,
                     compute=jnp.bfloat16):
    """Speculative-verify attention: S = k+1 positions of every row in ONE
    forward.  x: (B,S,D); pos: (B,) absolute position of x[:,0]; paged
    cache only (the engine gates speculation to pure-paged archs).

    Writes the S new KV rows at ``pos..pos+S-1`` (overflow past the table's
    reach lands in the scratch block), then attends each query with its own
    causal frontier ``cache_len = pos+s+1``.  The XLA fallback is a static
    per-query loop through `decode_attend` — the exact shapes, masks and
    f32-softmax reduction order of a plain decode step — which is what
    makes accepted speculative tokens bitwise-equal to spec="off" greedy
    decode.  Returns (out (B,S,D), new_cache)."""
    if cfg.mla is not None:
        return _mla_verify(x, p, cfg, cache, pos, block_tables=block_tables,
                           compute=compute)
    if "kp" not in cache:
        raise ValueError("attention_verify requires a paged KV cache")
    B, S, _ = x.shape
    pos = _row_positions(pos, B)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute))
    positions = pos[:, None] + jnp.arange(S)[None]             # (B, S)
    cos, sin = rope_table(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k_pool = _paged_write_seq(cache["kp"], k, block_tables, pos)
    v_pool = _paged_write_seq(cache["vp"], v, block_tables, pos)
    T = block_tables.shape[1] * k_pool.shape[1]
    if cfg.attn_impl == "pallas":
        from repro.kernels.paged_attention.ops import (
            paged_verify_attention, paged_verify_attention_tp, tp_heads)
        from repro.runtime.sharding import active_mesh
        mesh = active_mesh()
        if tp_heads(mesh, cfg.num_kv_heads, cfg.num_heads):
            out = paged_verify_attention_tp(q, k_pool, v_pool, block_tables,
                                            pos, mesh)
        else:
            out = paged_verify_attention(q, k_pool, v_pool, block_tables, pos)
    else:
        kg = _paged_gather(k_pool, block_tables)
        vg = _paged_gather(v_pool, block_tables)
        out = jnp.concatenate(
            [decode_attend(q[:, s:s + 1], kg, vg,
                           jnp.minimum(pos + s + 1, T))
             for s in range(S)], axis=1)
    out = jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))
    return out, {"kp": k_pool, "vp": v_pool}


def _mla_verify(x, p, cfg, cache, pos, *, block_tables, compute):
    """MLA speculative verify over the paged latent pools: per-query loop
    through `_mla_decode`'s absorbed-weight score math (same shapes, same
    masks, same reduction order — the bitwise-parity contract)."""
    s = cfg.mla
    if "ckvp" not in cache:
        raise ValueError("_mla_verify requires the paged latent pools")
    B, S, _ = x.shape
    pos = _row_positions(pos, B)
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)        # (B,S,H,*)
    positions = pos[:, None] + jnp.arange(S)[None]             # (B, S)
    cos, sin = rope_table(positions, s.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv_new = rmsnorm(kv_a[..., : s.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[:, :, None, s.kv_lora_rank:], cos, sin)[:, :, 0]
    ckv_pool = _paged_write_seq(cache["ckvp"], ckv_new, block_tables, pos)
    kr_pool = _paged_write_seq(cache["kropep"], kr_new, block_tables, pos)
    ckv = constrain_replicated(_paged_gather(ckv_pool, block_tables))
    krope = constrain_replicated(_paged_gather(kr_pool, block_tables))
    T = ckv.shape[1]

    wkv_b = p["wkv_b"].astype(compute)                         # (r,H,n+v)
    wk = wkv_b[..., : s.qk_nope_head_dim]
    wv = wkv_b[..., s.qk_nope_head_dim:]
    scale = 1.0 / np.sqrt(s.qk_head_dim)
    outs = []
    for sq in range(S):
        q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, sq], wk)
        scores = (
            jnp.einsum("bhr,btr->bht", q_lat, ckv.astype(compute),
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bhk,btk->bht", q_rope[:, sq], krope.astype(compute),
                         preferred_element_type=jnp.float32)
        ) * scale
        valid = (jnp.arange(T)[None]
                 < jnp.minimum(pos + sq + 1, T)[:, None])      # (B,T)
        scores = jnp.where(valid[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bht,btr->bhr", probs.astype(compute),
                             ckv.astype(compute),
                             preferred_element_type=jnp.float32)
        out = jnp.einsum("bhr,rhv->bhv", out_lat.astype(compute), wv)
        outs.append(jnp.einsum("bhv,hvd->bd", constrain_replicated(out),
                               p["wo"].astype(compute))[:, None])
    return (jnp.concatenate(outs, axis=1),
            {"ckvp": ckv_pool, "kropep": kr_pool})


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-attention-layer cache pytree (SWA: rolling buffer of window)."""
    if cfg.mla is not None:
        s = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, s.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, s.qk_rope_head_dim), dtype),
        }
    T = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, T, K, Dh), dtype),
        "v": jnp.zeros((batch, T, K, Dh), dtype),
    }


def init_kv_cache_paged(cfg, batch: int, max_len: int, num_blocks: int,
                        block_size: int, dtype=jnp.bfloat16):
    """Per-attention-layer PAGED cache: a shared block pool instead of a
    dense (batch, max_len) slab.  SWA layers keep the dense rolling ring —
    a window-sized ring is always fully live, so paging it saves nothing,
    and keeping it preserves bitwise decode parity with the dense path."""
    if cfg.sliding_window is not None and cfg.mla is None:
        return init_kv_cache(cfg, batch, max_len, dtype)
    if cfg.mla is not None:
        s = cfg.mla
        return {
            "ckvp": jnp.zeros((num_blocks, block_size, s.kv_lora_rank), dtype),
            "kropep": jnp.zeros((num_blocks, block_size, s.qk_rope_head_dim),
                                dtype),
        }
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "kp": jnp.zeros((num_blocks, block_size, K, Dh), dtype),
        "vp": jnp.zeros((num_blocks, block_size, K, Dh), dtype),
    }


# ==========================================================================
# MLA (multi-head latent attention)
# ==========================================================================

def _mla_project_q(x, p, cfg, compute):
    s = cfg.mla
    ql = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(compute))
    ql = rmsnorm(ql, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"].astype(compute))
    return q[..., : s.qk_nope_head_dim], q[..., s.qk_nope_head_dim:]


def _mla_forward(x, p, cfg, *, rope_cos, rope_sin, compute):
    """Training / prefill MLA with full expansion."""
    s = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)
    q_rope = apply_rope(q_rope, rope_cos, rope_sin)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv, k_rope = kv_a[..., : s.kv_lora_rank], kv_a[..., s.kv_lora_rank:]
    ckv = rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], rope_cos, rope_sin)  # (B,S,1,r)
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].astype(compute))
    k_nope = kv[..., : s.qk_nope_head_dim]
    v = kv[..., s.qk_nope_head_dim:]

    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "b.m.")
    k = constrain(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, s.qk_rope_head_dim))],
        axis=-1), "b.m.")
    # pad v head_dim up to qk_head_dim so the attend kernel sees square heads
    v_pad = constrain(jnp.pad(
        v, ((0, 0), (0, 0), (0, 0), (0, s.qk_head_dim - s.v_head_dim))),
        "b.m.")
    # the output constraint stops XLA sharding the score einsum's contraction
    # dim when H doesn't divide the model axis (minicpm3: 40 heads -> 10.6
    # TB/device of score all-reduces without this)
    out = constrain(attend(q, k, v_pad, cfg, causal=True), "b.m.")
    out = out[..., : s.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))


def _mla_decode(x, p, cfg, cache, pos, *, block_tables=None, compute):
    """Absorbed-weight MLA decode over the compressed latent cache.

    Caches only (kv_lora + rope_dim) per token — the MLA memory win.  The
    score is computed directly in latent space:
        score = q_nope·W_kv_b^K·ckv + q_rope·k_rope
    The latent cache pages like any other: {"ckvp","kropep"} pools plus the
    shared block table replace the dense (B, T) slabs.
    """
    s = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = _row_positions(pos, B)
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)          # (B,1,H,*)
    cos, sin = rope_table(pos[:, None], s.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv_new = rmsnorm(kv_a[..., : s.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[:, :, None, s.kv_lora_rank:], cos, sin)[:, :, 0]

    if "ckvp" in cache:                     # paged latent pool
        ckv_pool = _paged_write_rows(cache["ckvp"], ckv_new, block_tables, pos)
        kr_pool = _paged_write_rows(cache["kropep"], kr_new, block_tables, pos)
        ckv = _paged_gather(ckv_pool, block_tables)
        krope = _paged_gather(kr_pool, block_tables)
        T = ckv.shape[1]
        new_cache = {"ckvp": ckv_pool, "kropep": kr_pool}
    else:
        T = cache["ckv"].shape[1]
        slot = jnp.mod(pos, T)
        ckv = _ring_write_rows(cache["ckv"], ckv_new, slot)
        krope = _ring_write_rows(cache["krope"], kr_new, slot)
        new_cache = None                    # filled below (dense returns full)

    # serve TP: the latent pools shard on r — gather the rows whole so the
    # score/out contractions over r keep single-device reduction order
    ckv = constrain_replicated(ckv)
    krope = constrain_replicated(krope)
    wkv_b = p["wkv_b"].astype(compute)                           # (r,H,n+v)
    wk = wkv_b[..., : s.qk_nope_head_dim]                        # (r,H,n)
    wv = wkv_b[..., s.qk_nope_head_dim:]                         # (r,H,v)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], wk)         # absorb
    scale = 1.0 / np.sqrt(s.qk_head_dim)
    scores = (
        jnp.einsum("bhr,btr->bht", q_lat, ckv.astype(compute),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhk,btk->bht", q_rope[:, 0], krope.astype(compute),
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = jnp.arange(T)[None] < jnp.minimum(pos + 1, T)[:, None]   # (B,T)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bht,btr->bhr", probs.astype(compute),
                         ckv.astype(compute),
                         preferred_element_type=jnp.float32)     # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", out_lat.astype(compute), wv)
    out = jnp.einsum("bhv,hvd->bd", constrain_replicated(out), p["wo"].astype(compute))[:, None]
    return out, (new_cache if new_cache is not None
                 else {"ckv": ckv, "krope": krope})


# ==========================================================================
# Chunked prefill (paged serve path)
# ==========================================================================
#
# Admission prefill split into fixed-size chunks so running slots never see
# a stop-the-world prefill: each chunk writes its KV into the admitted
# row's blocks, then attends against everything cached so far (earlier
# chunks included) with a causal mask on absolute positions.  One batch
# row at a time — the other rows' decode state is untouched.


def _chunk_attend(q, k, v, q_pos, t_pos=None, window=None):
    """Causal attention of a prefill chunk against gathered cache KV.

    q: (1,C,H,Dh); k,v: (1,T,K,Dh); q_pos: (C,) absolute query positions;
    t_pos: (T,) absolute key positions (default 0..T-1; negatives are
    invalid — SWA pre-window slots).  f32 softmax like `decode_attend`.
    """
    B, C, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(Dh)
    if t_pos is None:
        t_pos = jnp.arange(T)
    qg = q.reshape(B, C, K, G, Dh).astype(jnp.bfloat16)
    s = jnp.einsum("bckgd,btkd->bkgct", qg, k.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) * scale
    valid = (t_pos[None, :] <= q_pos[:, None]) & (t_pos[None, :] >= 0)
    if window is not None:
        valid &= t_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgct,btkd->bckgd", p.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, H, Dh).astype(q.dtype)


def _ring_write_chunk_row(row, chunk, q_offset):
    """Write a chunk (C, ...) into one ring row (W, ...) keeping, per ring
    slot, the LATEST position ≤ q_offset+C-1 (deterministic gather-form of
    the rolling write; safe for any chunk/window ratio)."""
    W = row.shape[0]
    C = chunk.shape[0]
    r = jnp.arange(W)
    last = q_offset + C - 1
    p = last - jnp.mod(last - r, W)              # latest pos ≡ r (mod W)
    take = p >= q_offset
    src = jnp.take(chunk, jnp.clip(p - q_offset, 0, C - 1), axis=0)
    return jnp.where(
        jnp.reshape(take, (W,) + (1,) * (row.ndim - 1)),
        src.astype(row.dtype), row)


def attention_prefill_chunk(x, p, cfg, cache, table_row, slot, q_offset,
                            *, window=None, compute=jnp.bfloat16):
    """One prefill chunk of ONE batch row.  x: (1,C,D); cache: the full
    engine cache leaf (paged pools, or a dense SWA ring); table_row: (mb,)
    int32 physical block ids of the admitted row (passed explicitly — the
    engine installs the row into the shared block table only once the
    LAST chunk lands, so free-slot garbage writes keep hitting the scratch
    block mid-admission); slot: scalar int32 batch row; q_offset: scalar
    int32 absolute position of x[:,0].  Returns (out (1,C,D), new_cache)."""
    if cfg.mla is not None:
        return _mla_prefill_chunk(x, p, cfg, cache, table_row, slot,
                                  q_offset, compute=compute)
    C = x.shape[1]
    positions = q_offset + jnp.arange(C)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute))
    cos, sin = rope_table(positions[None], cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if "kp" in cache:                        # full attention: paged pool
        k_pool = _paged_write_chunk(cache["kp"], k[0], table_row, positions)
        v_pool = _paged_write_chunk(cache["vp"], v[0], table_row, positions)
        kg = _paged_gather(k_pool, table_row[None])      # (1,T,K,Dh)
        vg = _paged_gather(v_pool, table_row[None])
        out = _chunk_attend(q, kg, vg, positions)
        new_cache = {"kp": k_pool, "vp": v_pool}
    else:                                    # SWA: dense rolling ring
        W = cache["k"].shape[1]
        k_row = jax.lax.dynamic_index_in_dim(cache["k"], slot, 0, False)
        v_row = jax.lax.dynamic_index_in_dim(cache["v"], slot, 0, False)
        # chronological snapshot of the last W cached positions BEFORE the
        # chunk writes over them (ring slot of position p is p mod W)
        p_prev = q_offset - W + jnp.arange(W)
        k_prev = jnp.take(k_row, jnp.mod(p_prev, W), axis=0)
        v_prev = jnp.take(v_row, jnp.mod(p_prev, W), axis=0)
        k_all = jnp.concatenate([k_prev[None], k], axis=1)   # (1,W+C,K,Dh)
        v_all = jnp.concatenate([v_prev[None], v], axis=1)
        t_pos = jnp.concatenate([p_prev, positions])
        out = _chunk_attend(q, k_all, v_all, positions, t_pos=t_pos,
                            window=window)
        new_k = _ring_write_chunk_row(k_row, k[0], q_offset)
        new_v = _ring_write_chunk_row(v_row, v[0], q_offset)
        new_cache = {
            "k": jax.lax.dynamic_update_index_in_dim(
                cache["k"], new_k.astype(cache["k"].dtype), slot, 0),
            "v": jax.lax.dynamic_update_index_in_dim(
                cache["v"], new_v.astype(cache["v"].dtype), slot, 0),
        }
    out = jnp.einsum("bshk,hkd->bsd", constrain_replicated(out), p["wo"].astype(compute))
    return out, new_cache


def _mla_prefill_chunk(x, p, cfg, cache, table_row, slot, q_offset, *,
                       compute):
    """Chunked MLA prefill via the absorbed-weight latent score (same math
    as `_mla_decode`, vectorized over the chunk's C query positions)."""
    s = cfg.mla
    B, C, _ = x.shape
    positions = q_offset + jnp.arange(C)
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)      # (1,C,H,*)
    cos, sin = rope_table(positions[None], s.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv_new = rmsnorm(kv_a[..., : s.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[:, :, None, s.kv_lora_rank:], cos, sin)[:, :, 0]

    ckv_pool = _paged_write_chunk(cache["ckvp"], ckv_new[0], table_row,
                                  positions)
    kr_pool = _paged_write_chunk(cache["kropep"], kr_new[0], table_row,
                                 positions)
    ckv = constrain_replicated(
        _paged_gather(ckv_pool, table_row[None]))            # (1,T,r)
    krope = constrain_replicated(_paged_gather(kr_pool, table_row[None]))
    T = ckv.shape[1]

    wkv_b = p["wkv_b"].astype(compute)                       # (r,H,n+v)
    wk = wkv_b[..., : s.qk_nope_head_dim]
    wv = wkv_b[..., s.qk_nope_head_dim:]
    q_lat = jnp.einsum("bchn,rhn->bchr", q_nope, wk)
    scale = 1.0 / np.sqrt(s.qk_head_dim)
    scores = (
        jnp.einsum("bchr,btr->bhct", q_lat, ckv.astype(compute),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bchk,btk->bhct", q_rope, krope.astype(compute),
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = jnp.arange(T)[None, :] <= positions[:, None]     # (C,T)
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhct,btr->bchr", probs.astype(compute),
                         ckv.astype(compute),
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bchr,rhv->bchv", out_lat.astype(compute), wv)
    out = jnp.einsum("bchv,hvd->bcd", constrain_replicated(out), p["wo"].astype(compute))
    return out, {"ckvp": ckv_pool, "kropep": kr_pool}
