"""Attention: GQA/MQA, sliding-window, MLA; train/prefill and decode paths.

Three implementations of the core attend step (selected by cfg.attn_impl):

* ``chunked``  — pure-JAX flash-style online softmax, lax.scan over KV chunks.
  Memory O(S·d + chunk) instead of O(S²); FLOPs equal to full attention
  (every (q,kv) chunk pair is computed, masked ones included).  This is the
  paper-faithful baseline path used by the dry-run.
* ``causal_blocked`` — beyond-paper compute optimization: static triangular
  iteration over (q-block, kv-block) pairs skips fully-masked kv blocks,
  halving causal-attention FLOPs (and bounding SWA to O(S·window)).
* ``pallas`` — TPU Pallas kernel (repro.kernels.flash_attention); validated
  in interpret mode on CPU, used on real TPU hardware.

Decode attends a single new token against a KV cache.  For ``long_500k``
(batch=1) the cache sequence dim is sharded over the "model" axis and the
softmax reductions become XLA-SPMD all-reduces — exactly flash-decode
split-K, derived by the partitioner instead of hand-written NCCL.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense_init, rmsnorm, rope_table
from repro.runtime.sharding import constrain

NEG_INF = -1e30


# ==========================================================================
# Parameter init
# ==========================================================================

def init_attention(key, cfg):
    if cfg.mla is not None:
        return _init_mla(key, cfg)
    D, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H, Dh)),
        "wk": dense_init(ks[1], (D, K, Dh)),
        "wv": dense_init(ks[2], (D, K, Dh)),
        "wo": dense_init(ks[3], (H, Dh, D), in_axis=0),
    }


def _init_mla(key, cfg):
    s = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (D, s.q_lora_rank)),
        "q_norm": jnp.zeros((s.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], (s.q_lora_rank, H, s.qk_head_dim)),
        "wkv_a": dense_init(ks[2], (D, s.kv_lora_rank + s.qk_rope_head_dim)),
        "kv_norm": jnp.zeros((s.kv_lora_rank,), jnp.float32),
        "wkv_b": dense_init(ks[3], (s.kv_lora_rank, H, s.qk_nope_head_dim + s.v_head_dim)),
        "wo": dense_init(ks[4], (H, s.v_head_dim, D), in_axis=0),
    }


# ==========================================================================
# Core attend: (q, k, v) -> out, several implementations
# ==========================================================================

def _gqa_shapes(q, k):
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    return B, S, H, K, G, Dh


def _mask_chunk(q_pos, t_pos, causal, window):
    """(S, Ck) boolean validity mask."""
    m = jnp.ones((q_pos.shape[0], t_pos.shape[0]), bool)
    if causal:
        m &= t_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= t_pos[None, :] > (q_pos[:, None] - window)
    return m


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      chunk=1024):
    """Flash-style online-softmax attention, scanning KV chunks.

    q: (B,S,H,Dh); k,v: (B,T,K,Dh).  q_offset: absolute position of q[0]
    (prefill continuation / blocked iteration).  Returns (B,S,H,Dh).
    """
    B, S, H, K, G, Dh = _gqa_shapes(q, k)
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    scale = 1.0 / np.sqrt(Dh)

    qg = q.reshape(B, S, K, G, Dh).astype(jnp.bfloat16)
    kc = k.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        t_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        valid = _mask_chunk(q_pos, t_pos, causal, window)
        valid &= t_pos[None, :] < T            # padding
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(jnp.bfloat16),
                        vb.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    init = (
        jnp.full((B, K, G, S), NEG_INF, jnp.float32),
        jnp.zeros((B, K, G, S), jnp.float32),
        jnp.zeros((B, K, G, S, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def causal_blocked_attention(q, k, v, *, window=None, chunk=1024,
                             block_q=2048):
    """Triangular block iteration: q blocks are a static python loop, each
    attending only to its causal (and windowed) KV prefix.  Skips ~half the
    FLOPs of `chunked_attention` for causal masks; O(S·window) for SWA."""
    B, S, H, K, G, Dh = _gqa_shapes(q, k)
    T = k.shape[1]
    assert S == T, "blocked path is for self-attention (train/prefill)"
    block_q = min(block_q, S)
    if S % block_q:
        return chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    outs = []
    for i in range(S // block_q):
        q_lo, q_hi = i * block_q, (i + 1) * block_q
        kv_lo = 0
        if window is not None:
            kv_lo = max(0, (q_lo - window + 1) // chunk * chunk)
        kv_hi = q_hi
        qb = q[:, q_lo:q_hi]
        kb = k[:, kv_lo:kv_hi]
        vb = v[:, kv_lo:kv_hi]
        # positions inside the block are q_lo..q_hi-1; kv starts at kv_lo.
        # chunked_attention masks with absolute positions via q_offset.
        outs.append(
            _chunked_attention_abs(qb, kb, vb, q_offset=q_lo, kv_offset=kv_lo,
                                   window=window, chunk=chunk))
    return jnp.concatenate(outs, axis=1)


def _chunked_attention_abs(q, k, v, *, q_offset, kv_offset, window, chunk):
    """chunked_attention with an absolute kv offset (for blocked iteration)."""
    B, S, H, K, G, Dh = _gqa_shapes(q, k)
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, S, K, G, Dh).astype(jnp.bfloat16)
    kc = k.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, Dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        t_pos = kv_offset + idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        valid = _mask_chunk(q_pos, t_pos, True, window)
        valid &= t_pos[None, :] < kv_offset + T
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(jnp.bfloat16),
                        vb.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    init = (
        jnp.full((B, K, G, S), NEG_INF, jnp.float32),
        jnp.zeros((B, K, G, S), jnp.float32),
        jnp.zeros((B, K, G, S, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def attend(q, k, v, cfg, *, causal=True, window=None, q_offset=0):
    """Dispatch on cfg.attn_impl (self-attention, train/prefill)."""
    if cfg.attn_impl == "causal_blocked" and causal:
        return causal_blocked_attention(q, k, v, window=window,
                                        chunk=cfg.attn_chunk)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, chunk=cfg.attn_chunk)


def decode_attend(q, k_cache, v_cache, cache_len, *, window=None):
    """Single-token attention against a KV cache.

    q: (B,1,H,Dh); caches: (B,T,K,Dh); cache_len: scalar or (B,) count of
    valid entries per row (continuous batching gives every batch row its own
    position, so the lengths are ragged).  With T sharded over "model", the
    max/sum reductions lower to all-reduces = flash-decode split-K via SPMD.
    """
    B, _, H, Dh = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(Dh)
    qg = q.reshape(B, K, G, Dh).astype(jnp.bfloat16)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) * scale
    t_pos = jnp.arange(T)
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    valid = t_pos[None, :] < cl[:, None]                      # (B,T) ragged
    # Rolling SWA caches keep only the last `window` tokens, so every valid
    # slot is inside the window by construction; no extra masking needed.
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# ==========================================================================
# Full layer forward (projection + rope + attend + out-proj)
# ==========================================================================

def attention_forward(x, p, cfg, *, rope_cos, rope_sin, causal=True,
                      window=None, kv=None, compute=jnp.bfloat16):
    """Self- (kv=None) or cross- (kv=(k_in,)) attention over a full sequence.

    x: (B,S,D).  rope tables match S (None for cross-attention).
    """
    if cfg.mla is not None:
        return _mla_forward(x, p, cfg, rope_cos=rope_cos, rope_sin=rope_sin,
                            compute=compute)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute)),
                  "b.m.")
    src = x if kv is None else kv
    k = constrain(jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(compute)),
                  "b.m.")
    v = constrain(jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(compute)),
                  "b.m.")
    if rope_cos is not None:
        q = apply_rope(q, rope_cos, rope_sin)
        k = apply_rope(k, rope_cos, rope_sin)
    out = constrain(attend(q, k, v, cfg, causal=causal, window=window),
                    "b.m.")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute))


def _ring_write_full(k, v, cache, window=None):
    """Write a full prefill's k/v (B,S,K,Dh) into a (possibly rolling) cache
    (B,T,K,Dh), aligned so that slot = pos mod T."""
    S = k.shape[1]
    T = cache["k"].shape[1]
    if S <= T:
        kk = jnp.pad(k, ((0, 0), (0, T - S), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, T - S), (0, 0), (0, 0)))
        return {"k": kk.astype(cache["k"].dtype), "v": vv.astype(cache["v"].dtype)}
    # keep the latest occupant of each ring slot: pos = S-1 - ((S-1-slot) mod T)
    slot_ids = jnp.arange(T)
    pos = (S - 1) - jnp.mod((S - 1) - slot_ids, T)
    kk = jnp.take(k, pos, axis=1).astype(cache["k"].dtype)
    vv = jnp.take(v, pos, axis=1).astype(cache["v"].dtype)
    return {"k": kk, "v": vv}


def attention_prefill(x, p, cfg, rope, cache, *, window=None,
                      compute=jnp.bfloat16):
    """Full-sequence self-attention that also fills the decode cache.

    Returns (out (B,S,D), new_cache)."""
    if cfg.mla is not None:
        return _mla_prefill(x, p, cfg, rope, cache, compute=compute)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute)),
                  "b.m.")
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute)),
                  "b.m.")
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute)),
                  "b.m.")
    if rope[0] is not None:
        q = apply_rope(q, rope[0], rope[1])
        k = apply_rope(k, rope[0], rope[1])
    out = constrain(attend(q, k, v, cfg, causal=True, window=window), "b.m.")
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute))
    return out, _ring_write_full(k, v, cache, window)


def _mla_prefill(x, p, cfg, rope, cache, *, compute):
    """MLA prefill: full-expansion attention + compressed-latent cache fill."""
    s = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)
    q_rope = apply_rope(q_rope, rope[0], rope[1])
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv = rmsnorm(kv_a[..., : s.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[:, :, None, s.kv_lora_rank:], rope[0], rope[1])
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].astype(compute))
    k_nope = kv[..., : s.qk_nope_head_dim]
    v = kv[..., s.qk_nope_head_dim:]
    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "b.m.")
    k = constrain(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, s.qk_rope_head_dim))],
        axis=-1), "b.m.")
    v_pad = constrain(jnp.pad(
        v, ((0, 0), (0, 0), (0, 0), (0, s.qk_head_dim - s.v_head_dim))),
        "b.m.")
    out = constrain(attend(q, k, v_pad, cfg, causal=True), "b.m.")
    out = out[..., : s.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute))
    T = cache["ckv"].shape[1]
    ckv_w = jnp.pad(ckv, ((0, 0), (0, T - S), (0, 0))) if S <= T else ckv[:, -T:]
    kr = k_rope[:, :, 0]
    kr_w = jnp.pad(kr, ((0, 0), (0, T - S), (0, 0))) if S <= T else kr[:, -T:]
    return out, {"ckv": ckv_w.astype(cache["ckv"].dtype),
                 "krope": kr_w.astype(cache["krope"].dtype)}


def _row_positions(pos, batch: int):
    """Normalize a decode position to the per-row (B,) form.  Scalar `pos`
    (every row at the same absolute position — the wave-era contract) is
    broadcast; a (B,) vector (continuous batching) passes through."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


def _ring_write_rows(cache, new, slot):
    """Per-row ring-buffer write: cache (B,T,...), new (B,1,...), slot (B,).
    Each batch row lands at its own `pos mod T` — the vectorized form of the
    old scalar dynamic_update_slice."""
    upd = jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0))
    return upd(cache, new.astype(cache.dtype), slot)


def attention_decode(x, p, cfg, cache, pos, *, rope_theta=None,
                     window=None, compute=jnp.bfloat16):
    """One decode step.  x: (B,1,D); cache {"k","v"}: (B,T,K,Dh); pos:
    scalar or (B,) absolute position(s) of the new token — per-row positions
    are the continuous-batching path.  Returns (out, new_cache)."""
    if cfg.mla is not None:
        return _mla_decode(x, p, cfg, cache, pos, compute=compute)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    B = x.shape[0]
    pos = _row_positions(pos, B)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(compute))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(compute))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(compute))
    cos, sin = rope_table(pos[:, None], cfg.head_dim, theta)   # (B,1,dim/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    T = cache["k"].shape[1]
    # per-row ring-buffer write (rolling for SWA; plain append when T >= max)
    slot = jnp.mod(pos, T)
    k_cache = _ring_write_rows(cache["k"], k, slot)
    v_cache = _ring_write_rows(cache["v"], v, slot)
    cache_len = jnp.minimum(pos + 1, T)
    if cfg.attn_impl == "pallas":
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q[:, 0], k_cache, v_cache,
                               cache_len)[:, None]
    else:
        out = decode_attend(q, k_cache, v_cache, cache_len, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute))
    return out, {"k": k_cache, "v": v_cache}


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-attention-layer cache pytree (SWA: rolling buffer of window)."""
    if cfg.mla is not None:
        s = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, s.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, s.qk_rope_head_dim), dtype),
        }
    T = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, T, K, Dh), dtype),
        "v": jnp.zeros((batch, T, K, Dh), dtype),
    }


# ==========================================================================
# MLA (multi-head latent attention)
# ==========================================================================

def _mla_project_q(x, p, cfg, compute):
    s = cfg.mla
    ql = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(compute))
    ql = rmsnorm(ql, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"].astype(compute))
    return q[..., : s.qk_nope_head_dim], q[..., s.qk_nope_head_dim:]


def _mla_forward(x, p, cfg, *, rope_cos, rope_sin, compute):
    """Training / prefill MLA with full expansion."""
    s = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)
    q_rope = apply_rope(q_rope, rope_cos, rope_sin)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv, k_rope = kv_a[..., : s.kv_lora_rank], kv_a[..., s.kv_lora_rank:]
    ckv = rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], rope_cos, rope_sin)  # (B,S,1,r)
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].astype(compute))
    k_nope = kv[..., : s.qk_nope_head_dim]
    v = kv[..., s.qk_nope_head_dim:]

    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "b.m.")
    k = constrain(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, s.qk_rope_head_dim))],
        axis=-1), "b.m.")
    # pad v head_dim up to qk_head_dim so the attend kernel sees square heads
    v_pad = constrain(jnp.pad(
        v, ((0, 0), (0, 0), (0, 0), (0, s.qk_head_dim - s.v_head_dim))),
        "b.m.")
    # the output constraint stops XLA sharding the score einsum's contraction
    # dim when H doesn't divide the model axis (minicpm3: 40 heads -> 10.6
    # TB/device of score all-reduces without this)
    out = constrain(attend(q, k, v_pad, cfg, causal=True), "b.m.")
    out = out[..., : s.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(compute))


def _mla_decode(x, p, cfg, cache, pos, *, compute):
    """Absorbed-weight MLA decode over the compressed latent cache.

    Caches only (kv_lora + rope_dim) per token — the MLA memory win.  The
    score is computed directly in latent space:
        score = q_nope·W_kv_b^K·ckv + q_rope·k_rope
    """
    s = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = _row_positions(pos, B)
    q_nope, q_rope = _mla_project_q(x, p, cfg, compute)          # (B,1,H,*)
    cos, sin = rope_table(pos[:, None], s.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(compute))
    ckv_new = rmsnorm(kv_a[..., : s.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[:, :, None, s.kv_lora_rank:], cos, sin)[:, :, 0]

    T = cache["ckv"].shape[1]
    slot = jnp.mod(pos, T)
    ckv = _ring_write_rows(cache["ckv"], ckv_new, slot)
    krope = _ring_write_rows(cache["krope"], kr_new, slot)

    wkv_b = p["wkv_b"].astype(compute)                           # (r,H,n+v)
    wk = wkv_b[..., : s.qk_nope_head_dim]                        # (r,H,n)
    wv = wkv_b[..., s.qk_nope_head_dim:]                         # (r,H,v)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], wk)         # absorb
    scale = 1.0 / np.sqrt(s.qk_head_dim)
    scores = (
        jnp.einsum("bhr,btr->bht", q_lat, ckv.astype(compute),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhk,btk->bht", q_rope[:, 0], krope.astype(compute),
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = jnp.arange(T)[None] < jnp.minimum(pos + 1, T)[:, None]   # (B,T)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bht,btr->bhr", probs.astype(compute),
                         ckv.astype(compute),
                         preferred_element_type=jnp.float32)     # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", out_lat.astype(compute), wv)
    out = jnp.einsum("bhv,hvd->bd", out, p["wo"].astype(compute))[:, None]
    return out, {"ckv": ckv, "krope": krope}
