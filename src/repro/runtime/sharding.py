"""Logical->physical sharding rules (TP / FSDP / EP / sequence-parallel).

One table drives everything: each parameter leaf name maps to a
(tensor-parallel dim, FSDP dim) pair in *negative* indexing, which makes the
rules invariant to the scan-stacking group dim (and to MoE's expert dim for
up/gate/down, which share names with the dense MLP).

Divisibility is always checked: a dim is only sharded if the axis (product)
divides it; otherwise the rule degrades gracefully (FSDP tries
("pod","data") -> ("data",) -> ("pod",) -> replicate).  This is what lets a
single rule set serve all 10 assigned architectures (e.g. minicpm3's 40
heads don't divide model=16 -> its TP lands on latent ranks and d_ff
instead; gemma's single KV head is replicated).

Modes:
* "train"  — TP on the model axis + FSDP (ZeRO-3) over the batch axes for
             params AND optimizer moments; batch over ("pod","data").
* "serve"  — TP only; params replicated over batch axes; decode caches are
             sequence-sharded over "model" (flash-decode split-K) and
             batch-sharded over ("pod","data").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

from repro.runtime.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(mesh: Mesh, layout: str = "2d"):
    """Physical axes carrying the batch.  layout "fsdp" folds the model axis
    into the batch/FSDP dimension (no tensor parallelism) — the right call
    for archs whose head counts don't divide the model axis (replicated
    attention under TP) and whose optimizer state fits when sharded over all
    chips."""
    pool = ((POD_AXIS, DATA_AXIS, MODEL_AXIS) if layout == "fsdp"
            else (POD_AXIS, DATA_AXIS))
    axes = tuple(a for a in pool if a in mesh.axis_names)
    return axes if axes else None


def _fsdp_candidates(mesh: Mesh, layout: str = "2d"):
    cands = []
    ba = batch_axes(mesh, layout)
    if ba:
        cands.append(ba)
        if len(ba) > 2:
            cands.append(ba[:2])
            cands.append(ba[1:])
        for a in ba:
            cands.append((a,))
    return cands


def _choose_fsdp(mesh: Mesh, dim_size: int, layout: str = "2d"):
    for cand in _fsdp_candidates(mesh, layout):
        if dim_size % axis_size(mesh, cand) == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


def _maybe(mesh: Mesh, axis, dim_size: int):
    return axis if (axis in mesh.axis_names and dim_size % axis_size(mesh, axis) == 0) else None


# --------------------------------------------------------------------------
# parameter rules: name -> (tp_dim, fsdp_dim), negative indices
# --------------------------------------------------------------------------

_PARAM_RULES: dict[str, tuple[int | None, int | None]] = {
    "embed":    (-2, -1),   # (V, D): vocab over model, D FSDP
    "head":     (-1, -2),   # (D, V)
    "wq":       (-2, -3),   # (..., D, H, Dh)
    "wk":       (-2, -3),
    "wv":       (-2, -3),
    "wo":       (-3, -1),   # (..., H, Dh, D)
    "wq_a":     (-1, -2),   # (..., D, r)
    "wq_b":     (-2, -3),   # (..., r, H, k)
    "wkv_a":    (-1, -2),
    "wkv_b":    (-2, -3),
    "up":       (-1, -2),   # dense (..., D, F) and MoE (..., E, D, F)
    "gate":     (-1, -2),
    "down":     (-2, -1),   # dense (..., F, D) and MoE (..., E, F, D)
    "router":   (None, -2),
    "in_proj":  (-1, -2),   # (..., D, Z)
    "out_proj": (-2, -1),   # (..., d_inner, D)
    "conv_w":   (-1, None),
    "conv_b":   (-1, None),
}

_MOE_NAMES = ("up", "gate", "down")


def _leaf_name(path) -> str:
    for k in reversed(path):
        if isinstance(k, DictKey):
            return str(k.key)
        if isinstance(k, GetAttrKey):
            return str(k.name)
    return ""


def _is_moe_leaf(path, ndim: int, name: str) -> bool:
    # MoE up/gate/down are 3-D (+1 stacked group dim = 4-D); dense are 2/3-D
    if name not in _MOE_NAMES:
        return False
    return ndim == (4 if _stacked(path) else 3)


def _stacked(path) -> bool:
    """True if the leaf lives under the scanned layer stack."""
    return any(isinstance(k, DictKey) and str(k.key) in
               ("layers", "enc_layers", "dec_layers") for k in path)


def param_spec(path, shape, mesh: Mesh, mode: str, *,
               moe_partition: str = "tp", layout: str = "2d") -> P:
    name = _leaf_name(path)
    ndim = len(shape)
    if name not in _PARAM_RULES or ndim == 0:
        return P()
    tp_dim, fsdp_dim = _PARAM_RULES[name]
    spec: list = [None] * ndim

    def put(dim, axis):
        if dim is None or axis is None:
            return
        if -dim > ndim:
            return
        if spec[dim % ndim] is None:
            spec[dim % ndim] = axis

    if layout != "fsdp":
        if moe_partition == "ep" and _is_moe_leaf(path, ndim, name):
            e_dim = -3
            if mode == "serve":
                # decode weight streaming: experts over the (idle) data
                # axis AND expert hidden over model — combined E*F sharding
                if shape[e_dim % ndim] % axis_size(mesh, DATA_AXIS) == 0:
                    put(e_dim, DATA_AXIS)
                if tp_dim is not None and -tp_dim <= ndim:
                    put(tp_dim, _maybe(mesh, MODEL_AXIS, shape[tp_dim % ndim]))
            # train: experts over the model axis (token all-to-all dispatch)
            elif shape[e_dim % ndim] % axis_size(mesh, MODEL_AXIS) == 0:
                put(e_dim, MODEL_AXIS)
        else:
            if tp_dim is not None and -tp_dim <= ndim:
                put(tp_dim, _maybe(mesh, MODEL_AXIS, shape[tp_dim % ndim]))
    if mode == "train" and fsdp_dim is not None and -fsdp_dim <= ndim:
        if spec[fsdp_dim % ndim] is None:
            put(fsdp_dim, _choose_fsdp(mesh, shape[fsdp_dim % ndim], layout))
    return P(*spec)


def param_shardings(param_specs_tree, mesh: Mesh, mode: str, *,
                    moe_partition: str = "tp", layout: str = "2d"):
    """param_specs_tree: pytree of ShapeDtypeStruct (or arrays)."""
    def one(path, leaf):
        return NamedSharding(
            mesh, param_spec(path, leaf.shape, mesh, mode,
                             moe_partition=moe_partition, layout=layout))
    return jax.tree_util.tree_map_with_path(one, param_specs_tree)


# --------------------------------------------------------------------------
# batch / decode-state rules
# --------------------------------------------------------------------------

def _batch_dim_axis(mesh: Mesh, b: int, layout: str = "2d"):
    ba = batch_axes(mesh, layout)
    if not ba:
        return None
    if b % axis_size(mesh, ba) == 0:
        return ba if len(ba) > 1 else ba[0]
    if len(ba) > 2:
        for cand in (ba[:2], ba[1:]):
            if b % axis_size(mesh, cand) == 0:
                return cand
    for a in ba:
        if b % axis_size(mesh, a) == 0:
            return a
    return None


def batch_shardings(batch_specs, mesh: Mesh, layout: str = "2d"):
    """tokens/targets (B,S) -> batch over (pod,data); frontend (B,F,D) same."""
    def one(path, leaf):
        spec = [None] * len(leaf.shape)
        spec[0] = _batch_dim_axis(mesh, leaf.shape[0], layout)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_specs)


def decode_state_shardings(state_specs, mesh: Mesh):
    """Decode caches: batch dim over (pod,data); the long sequence dim (self-
    attn KV / MLA latent) over "model" (split-K); SSM state heads over
    "model".  Leaf kinds are identified structurally by name."""
    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        ndim = len(shape)
        spec: list = [None] * ndim
        if name == "pos":
            return NamedSharding(mesh, P())
        if name == "token":
            spec[0] = _batch_dim_axis(mesh, shape[0])
            return NamedSharding(mesh, P(*spec))
        # cache leaves: possibly stacked (n_groups first).  Identify batch dim
        # as the dim right after the stack dim (if stacked) else dim 0.
        bdim = 1 if _stacked_cache(path) else 0
        if ndim > bdim:
            spec[bdim] = _batch_dim_axis(mesh, shape[bdim])
        if name in ("k", "v", "ckv", "krope"):
            tdim = bdim + 1
            if ndim > tdim and shape[tdim] % axis_size(mesh, MODEL_AXIS) == 0:
                spec[tdim] = MODEL_AXIS
        elif name == "ssd":                      # (..., B, H, N, P)
            hdim = bdim + 1
            if ndim > hdim and shape[hdim] % axis_size(mesh, MODEL_AXIS) == 0:
                spec[hdim] = MODEL_AXIS
        elif name == "conv":                     # (..., B, W-1, conv_dim)
            cdim = bdim + 2
            if ndim > cdim and shape[cdim] % axis_size(mesh, MODEL_AXIS) == 0:
                spec[cdim] = MODEL_AXIS
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, state_specs)


def _stacked_cache(path) -> bool:
    """Cache pytrees: a list of per-slot dicts whose leaves carry the group
    dim first (decoder caches), or dicts under "self"/"cross" (encdec, leading
    layer dim)."""
    for k in path:
        if isinstance(k, SequenceKey):
            return True
        if isinstance(k, DictKey) and str(k.key) in ("self", "cross"):
            return True
    return False


def serve_state_shardings(state_specs, mesh: Mesh):
    """Serve-engine decode state under tensor parallelism: KV pools shard on
    the HEAD dim over "model", never on the sequence/block dim.

    This is deliberately different from :func:`decode_state_shardings`
    (split-K over the sequence dim): splitting the KV sequence changes the
    attention reduction order and breaks the engine's bitwise
    sharded-vs-single-device parity guarantee.  Splitting heads keeps every
    per-head softmax+weighted-sum bitwise identical to the single-device
    kernel — each shard owns whole heads.

    Rules (dims in trailing/negative indexing, stacked group dim invariant):
      kp/vp       (nb, bs, K, Dh)        -> K (dim -2) over "model"
      ckvp        (nb, bs, r_latent)     -> latent (dim -1) over "model"
      kropep      (nb, bs, d_rope)       -> latent (dim -1) over "model"
      k/v dense   (B, T, K, Dh)          -> K (dim -2) over "model"
      ckv/krope dense (B, T, r)          -> latent (dim -1) over "model"
      ssd/conv / token / pos / block_tables -> replicated
    Every rule degrades to replication when the axis doesn't divide the dim.
    """
    msz = axis_size(mesh, MODEL_AXIS)

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        ndim = len(shape)
        spec: list = [None] * ndim
        if msz > 1 and ndim >= 2:
            if name in ("kp", "vp"):
                if shape[-2] % msz == 0:
                    spec[ndim - 2] = MODEL_AXIS
            elif name in ("ckvp", "kropep"):
                if shape[-1] % msz == 0:
                    spec[ndim - 1] = MODEL_AXIS
            elif name in ("k", "v"):
                if ndim >= 3 and shape[-2] % msz == 0:
                    spec[ndim - 2] = MODEL_AXIS
            elif name in ("ckv", "krope"):
                if shape[-1] % msz == 0:
                    spec[ndim - 1] = MODEL_AXIS
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, state_specs)


_SERVE_TP_SAFE = frozenset(
    {"embed", "head", "wq", "wk", "wv", "wq_b", "wkv_b", "up", "gate"})


def serve_param_shardings(tree, mesh: Mesh):
    """Order-preserving tensor parallelism for the serve engine.

    Only COLUMN-parallel weights shard — those whose TP dim is an *output*
    dim of the forward contraction (wq/wk/wv/up/gate/... split heads or
    d_ff; the contraction dim D/r stays whole on every shard, so each
    shard's outputs are bitwise identical to the single-device slices).
    ROW-parallel weights (wo, down, out_proj: TP dim is the contraction
    dim) are deliberately replicated: sharding them turns the contraction
    into partial sums combined by psum, whose reduction order differs from
    the single-device einsum and flips argmax on near-tie logits — which
    breaks the engine's bitwise sharded-vs-single-device token parity
    guarantee.  wq_a/wkv_a are also replicated (their outputs feed rmsnorm
    over the latent dim, a reduction that must not be sharded).

    The memory win that matters for serving — the paged KV pools — comes
    from :func:`serve_state_shardings`, not from here.
    """
    def one(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        if name not in _SERVE_TP_SAFE or ndim == 0:
            return NamedSharding(mesh, P())
        tp_dim, _ = _PARAM_RULES[name]
        spec: list = [None] * ndim
        if tp_dim is not None and -tp_dim <= ndim:
            spec[tp_dim % ndim] = _maybe(
                mesh, MODEL_AXIS, leaf.shape[tp_dim % ndim])
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def serve_param_shard_factor(path, shape, model_axis_size: int) -> int:
    """How many ways :func:`serve_param_shardings` would split this leaf
    on a mesh with ``model_axis_size`` model shards — as a PURE divisor,
    no Mesh or devices required.  Mirrors the sharding rules exactly
    (column-parallel leaves only, divisibility-gated, else replicated),
    so a dry run can account per-device serve memory without building
    the mesh it is sizing for."""
    name = _leaf_name(path)
    ndim = len(shape)
    if model_axis_size <= 1 or name not in _SERVE_TP_SAFE or ndim == 0:
        return 1
    tp_dim, _ = _PARAM_RULES[name]
    if tp_dim is None or -tp_dim > ndim:
        return 1
    return (model_axis_size
            if shape[tp_dim % ndim] % model_axis_size == 0 else 1)


def serve_state_shard_factor(path, shape, model_axis_size: int) -> int:
    """Pure-divisor mirror of :func:`serve_state_shardings`: KV pools and
    dense caches split on the head/latent dim over the model axis when it
    divides, everything else (ssd/conv/token/pos/block_tables) replicates."""
    name = _leaf_name(path)
    ndim = len(shape)
    msz = model_axis_size
    if msz <= 1 or ndim < 2:
        return 1
    if name in ("kp", "vp"):
        return msz if shape[-2] % msz == 0 else 1
    if name in ("ckvp", "kropep"):
        return msz if shape[-1] % msz == 0 else 1
    if name in ("k", "v"):
        return msz if (ndim >= 3 and shape[-2] % msz == 0) else 1
    if name in ("ckv", "krope"):
        return msz if shape[-1] % msz == 0 else 1
    return 1


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# --------------------------------------------------------------------------
# train-state assembly
# --------------------------------------------------------------------------

def train_state_shardings(param_specs_tree, mesh: Mesh, *,
                          moe_partition: str = "tp", layout: str = "2d"):
    ps = param_shardings(param_specs_tree, mesh, "train",
                         moe_partition=moe_partition, layout=layout)
    return {
        "params": ps,
        "opt": {
            "m": ps,
            "v": ps,
            "step": NamedSharding(mesh, P()),
        },
    }


# --------------------------------------------------------------------------
# activation sharding constraints (MaxText-style)
# --------------------------------------------------------------------------
# XLA's sharding propagation loses the batch axis inside the BACKWARD
# while-loop of grad(checkpoint(scan(...))) — cotangents and remat recompute
# then run with a replicated batch (measured: 260x the ideal per-device
# FLOPs on smollm train_4k).  The production fix is explicit
# with_sharding_constraint on activations inside the scan body; these
# helpers are no-ops unless a mesh context is active, so model code stays
# pure for tests/smoke runs.

import threading as _threading
from contextlib import contextmanager

_ACT = _threading.local()


@contextmanager
def activation_sharding(mesh: Mesh, layout: str = "2d"):
    prev = getattr(_ACT, "ctx", None)
    _ACT.ctx = (mesh, layout)
    try:
        yield
    finally:
        _ACT.ctx = prev


def active_mesh() -> Mesh | None:
    """The mesh of the enclosing :func:`activation_sharding` context, or
    None.  Read at TRACE time — model code uses it to pick sharded kernel
    dispatch (shard_map over the head axis) without carrying a mesh through
    every call signature."""
    ctx = getattr(_ACT, "ctx", None)
    return ctx[0] if ctx is not None else None


def constrain_replicated(x):
    """Pin an activation fully replicated — fires only under a "serve"
    layout context (the serve engine's SPMD step/prefill traces).

    Placed immediately BEFORE every contraction whose reduction dim can be
    sharded (the wo out-projection over heads, the MLP down over d_ff, MLA
    score/out math over gathered latents): forces GSPMD to all-gather the
    operand and run the reduction whole on every device — the same
    canonical order as the single-device engine — instead of the cheaper
    partial-sum + psum, whose low-bit differences flip argmax on near-tie
    logits and break bitwise token parity."""
    ctx = getattr(_ACT, "ctx", None)
    if ctx is None or ctx[1] != "serve":
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx[0], P()))


def constrain(x, dims: str):
    """Constrain an activation if a mesh context is active.

    ``dims`` has one char per array dim:
      'b' -> batch axes (pod+data, +model under the "fsdp" layout)
      'm' -> model axis (tensor-parallel dim; skipped under "fsdp")
      'd' -> data axis (serve-mode expert parallelism)
      '.' -> unconstrained
    Axes are applied only when they divide the dim size (graceful degrade,
    same rule as the parameter table).  Conflicting axis use (e.g. batch and
    experts both wanting "data") skips the constraint.
    """
    ctx = getattr(_ACT, "ctx", None)
    if ctx is None:
        return x
    mesh, layout = ctx
    assert len(dims) == x.ndim, (dims, x.shape)
    spec = []
    for ch, size in zip(dims, x.shape):
        if ch == "b":
            spec.append(_batch_dim_axis(mesh, size, layout))
        elif ch == "m" and layout != "fsdp":
            spec.append(_maybe(mesh, MODEL_AXIS, size))
        elif ch == "d":
            spec.append(_maybe(mesh, DATA_AXIS, size))
        else:
            spec.append(None)
    flat = []
    for s in spec:
        if s is not None:
            flat.extend(s if isinstance(s, tuple) else (s,))
    if len(flat) != len(set(flat)):     # conflicting axes -> skip
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
