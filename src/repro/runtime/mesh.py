"""Mesh construction helpers.

The production mesh (see launch/mesh.py) is (data=16, model=16) per pod and
(pod=2, data=16, model=16) for the multi-pod dry-run.  Everything in this
module is a pure function of an existing `jax.sharding.Mesh`; importing it
never touches jax device state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
from jax.sharding import Mesh

# Canonical physical axis names, outermost first.  "pod" is the slowest /
# cross-ICI axis, "data" is the pure-replication/batch axis, "model" is the
# tensor-parallel axis (fast ICI ring).
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
ALL_AXES = (POD_AXIS, DATA_AXIS, MODEL_AXIS)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative mesh description (used by configs and the pilot system).

    A PilotSlice is provisioned against a MeshSpec; the payload never gets to
    change it (late binding swaps the executable, not the resource grant).
    """

    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")
        for a in self.axes:
            if a not in ALL_AXES:
                raise ValueError(f"unknown mesh axis {a!r}; expected {ALL_AXES}")

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]

    def build(self, devices: Sequence[jax.Device] | None = None) -> Mesh:
        if devices is None:
            return jax.make_mesh(self.shape, self.axes)
        import numpy as np

        devs = np.asarray(devices).reshape(self.shape)
        return Mesh(devs, self.axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return MeshSpec(tuple(shape), tuple(axes)).build()


def parse_mesh_shape(text: str) -> tuple[int, ...]:
    """Parse the CLI/image mesh-shape syntax ``"AxB"`` (e.g. ``"1x2"``,
    ``"2x4"``) into a shape tuple.  A bare integer means ``1xN`` (pure
    tensor parallelism)."""
    parts = [p for p in str(text).lower().split("x") if p]
    if not parts:
        raise ValueError(f"bad mesh shape {text!r}; expected 'AxB'")
    try:
        shape = tuple(int(p) for p in parts)
    except ValueError as e:
        raise ValueError(f"bad mesh shape {text!r}; expected 'AxB'") from e
    if any(s < 1 for s in shape):
        raise ValueError(f"bad mesh shape {text!r}; dims must be >= 1")
    if len(shape) == 1:
        shape = (1,) + shape
    if len(shape) != 2:
        raise ValueError(f"bad mesh shape {text!r}; serve meshes are 2-D "
                         f"(data x model)")
    return shape


def serve_mesh_spec(shape: tuple[int, ...] | str) -> MeshSpec:
    """The serve-path mesh: ``(data, model)``.  The model axis carries the
    tensor-parallel shards of params and paged-KV pools; the data axis is
    pure replication headroom (slots are not batch-sharded in serve)."""
    if isinstance(shape, str):
        shape = parse_mesh_shape(shape)
    shape = tuple(int(s) for s in shape)
    if len(shape) != 2:
        raise ValueError(f"serve mesh shape must be 2-D (data, model), "
                         f"got {shape}")
    return MeshSpec(shape, (DATA_AXIS, MODEL_AXIS))


def serve_mesh(shape: tuple[int, ...] | str,
               devices: Sequence[jax.Device] | None = None) -> Mesh:
    """Build the serve mesh for ``shape`` (``"AxB"`` or a tuple)."""
    return serve_mesh_spec(shape).build(devices)


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    """Size of a named axis; 1 if the mesh does not have it."""
    return mesh.shape.get(name, 1) if hasattr(mesh.shape, "get") else dict(
        zip(mesh.axis_names, mesh.devices.shape)
    ).get(name, 1)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Physical axes the global batch is sharded over (pod+data)."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.axis_names)


def batch_parallelism(mesh: Mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh_axis_size(mesh, a)
    return out


def model_parallelism(mesh: Mesh) -> int:
    return mesh_axis_size(mesh, MODEL_AXIS)
