"""Int8 error-feedback gradient compression (beyond-paper optimization).

Before the data-parallel gradient reduction, each gradient leaf is quantized
to int8 with a per-leaf scale; the quantization error is kept locally and
added back to the next step's gradient (error feedback keeps SGD/Adam
convergence — 1-bit Adam / EF-SGD lineage).  On a real fleet this shrinks
the reduce-scatter payload 4x (f32->i8); under XLA SPMD we model the
transport by quantize->dequantize around the (automatic) reduction and
account the byte savings in the roofline's collective term.

Pure-functional: residual state lives in the train state next to the
optimizer moments and shards identically to the parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _quantize_leaf(g, r):
    g = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    dq = q.astype(jnp.float32) * scale
    return dq, g - dq


def compress(grads, residuals):
    """Returns (dequantized grads, new residuals).  Transport payload is the
    int8 tensor + one f32 scale per leaf."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [_quantize_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    dq = tdef.unflatten([o[0] for o in out])
    res = tdef.unflatten([o[1] for o in out])
    return dq, res


def payload_bytes(grads) -> tuple[int, int]:
    """(uncompressed_bytes, compressed_bytes) for the DP reduction payload."""
    raw = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(grads))
    comp = sum(l.size * 1 + 4 for l in jax.tree.leaves(grads))
    return raw, comp
