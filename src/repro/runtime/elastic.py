"""Elastic scaling: live-pilot membership -> mesh + reshard plan.

The model axis is fixed per slice (a payload's TP degree is baked into its
compiled executable); the data axis grows/shrinks with the live-pilot set.
Membership changes therefore never require resharding *within* a slice —
they change how many slices the repo fans batches out to, and training
payloads resume from the last checkpoint with a recomputed data axis.

`plan_remesh` is pure host logic: given old/new membership it emits a
ReshardPlan that the launcher executes through the checkpoint store
(save at old mesh -> restore at new mesh; per-leaf shapes are mesh-
independent so the numpy checkpoints are directly portable).
"""

from __future__ import annotations

import dataclasses

from repro.runtime.mesh import MeshSpec


@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    old_mesh: MeshSpec | None
    new_mesh: MeshSpec
    reason: str
    # batch re-split: global batch stays fixed; per-slice microbatch changes
    global_batch: int
    old_per_data: int | None
    new_per_data: int
    # instructions executed by the launcher
    actions: tuple[str, ...]


class NoViableMeshError(ValueError):
    """Fleet membership admits no mesh at all — e.g. every pilot is gone.

    An explicit outcome, not a bogus 1-slice plan: the caller must wait for
    capacity (or page an operator), never "resume" onto slices that do not
    exist."""


def viable_data_axis(n_live: int, global_batch: int) -> int:
    """Largest data-parallel degree <= n_live that divides global_batch.
    Raises :class:`NoViableMeshError` when there are no live slices — a
    fleet that lost every pilot has no data axis, not a data axis of 1."""
    if n_live <= 0:
        raise NoViableMeshError(
            f"no viable data axis: {n_live} live slices (the fleet is empty; "
            f"hold the workload and wait for capacity)")
    for d in range(min(n_live, global_batch), 0, -1):
        if global_batch % d == 0:
            return d
    return 1


def plan_remesh(old: MeshSpec | None, n_live_slices: int, model_parallel: int,
                global_batch: int, reason: str = "membership-change") -> ReshardPlan:
    if n_live_slices < 1:
        raise NoViableMeshError(
            f"no viable mesh: {n_live_slices} live slices "
            f"(reason={reason!r}); refusing to emit a remesh plan for an "
            f"empty fleet")
    data = viable_data_axis(n_live_slices, global_batch)
    new = MeshSpec((data, model_parallel), ("data", "model"))
    actions = ["drain-payloads", "checkpoint-if-training"]
    if old is not None and old.shape == new.shape:
        actions = ["no-op"]
    else:
        actions += ["rebuild-mesh", "restore-checkpoint", "resume"]
    return ReshardPlan(
        old_mesh=old,
        new_mesh=new,
        reason=reason,
        global_batch=global_batch,
        old_per_data=None if old is None else global_batch // old.axis_size("data"),
        new_per_data=global_batch // data,
        actions=tuple(actions),
    )
