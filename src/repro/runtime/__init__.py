"""Distribution substrate: mesh construction, sharding rules, elasticity,
gradient compression."""

from repro.runtime.mesh import MeshSpec, batch_axes, make_mesh, mesh_axis_size
from repro.runtime.sharding import (
    batch_shardings,
    decode_state_shardings,
    param_shardings,
    param_spec,
    replicated,
    train_state_shardings,
)

__all__ = [
    "MeshSpec", "batch_axes", "make_mesh", "mesh_axis_size",
    "batch_shardings", "decode_state_shardings", "param_shardings",
    "param_spec", "replicated", "train_state_shardings",
]
