"""Batched serving engine: continuous batching over a PAGED KV cache.

The engine owns a device-resident block pool per attention layer —
``(num_blocks, block_size, heads, dh)`` — plus a per-slot block table
``(slots, max_len // block_size)`` mapping logical position ``p`` of slot
``s`` to ``pool[table[s, p // bs], p % bs]``.  A host-side
:class:`~repro.serving.blockpool.BlockAllocator` (free list + refcounts)
hands out physical blocks at ADMISSION granularity: a request maps
exactly the blocks its prompt bucket + token budget can reach (not the
engine-wide ``max_len`` row a dense slab burns), and eviction returns
them all.  Allocating the whole row up front keeps the decode loop free
of host→device table maintenance — the block table is written once per
admission.  ``kv="dense"`` keeps the old (slots, max_len) slab as an
ablation — paged decode is bitwise-equal to it (same shapes, same masks,
same reduction order), which the CI smoke asserts.

On top of paging:

* **prefix reuse** — admission hashes the padded prompt per full block
  (chain hash, so a hit guarantees bit-identical KV); matching leading
  blocks are mapped into the slot's table copy-free with a refcount bump.
  One-shot admission still recomputes the whole prefill (reuse saves pool
  MEMORY); chunked admission additionally starts at the hit frontier and
  skips the shared blocks' compute.
  Shared blocks are copy-on-write safe by construction: only FULL blocks
  strictly below the write frontier are shared and nothing ever writes
  below the frontier, so the "copy" branch of COW is unreachable.
* **chunked prefill** (``prefill="chunked"``) — admission prefill is
  split into fixed-size chunks, at most ONE of which runs per engine
  tick, interleaved with the running slots' decode step: no decode step
  is ever delayed by more than one chunk (the stop-the-world admission
  of ``prefill="oneshot"`` is the ablation).  Mid-admission, the slot's
  device-side table row still points at the scratch block — the chunk
  executable carries the real row as an argument — so free-slot garbage
  writes cannot corrupt the half-prefilled request.

Per-slot ``pos`` invariants (unchanged from the dense engine):

* after admission into slot ``s`` with prompt bucket ``plen``,
  ``pos[s] == plen`` and logical rows ``0..plen-1`` hold the
  (left-padded) prompt KV;
* each decode step writes row ``s``'s KV at ``pos[s]`` and advances
  ``pos[s] += 1`` — rows never interact, so admitting a request
  mid-decode leaves every other slot's token stream bitwise identical
  to a solo run;
* a slot is evicted when ``pos[s]`` reaches ``max_len`` or its token
  budget is spent — both checked ON DEVICE; eviction returns every
  block the slot owned to the free list (refcount-decrement for shared
  prefix blocks);
* free slots keep stepping over garbage (cheaper than masking the
  batched matmuls); their paged writes land in the reserved scratch
  block 0, never in a live request's blocks.

One-transfer-per-step rule: the decode loop is device-resident; the
packed ``(2, slots)`` tokens/done array is the ONLY device→host transfer
per decode step (``d2h_transfers == steps``, asserted in tests).  Block-
table maintenance is host→device only.

In the pilot system this engine is a first-class *payload*: ``serve``
tasks late-bind it onto an already-held slice and drive it either from a
request trace in the startup spec or by leasing requests from a fleet
pool (core/images.py + core/wrapper.py + serving/dispatch.py); the serve
heartbeat telemetry now carries ``kv_memory_utilization`` and
``prefix_hit_rate`` so pilots report cache pressure upstream.  For the
fleet path the engine exposes per-request drain/export — ``cancel(rid)``
evicts a request wherever it lives and returns it for re-dispatch,
``drain_requests()`` exports everything — and ``warm_install()`` absorbs
the admission-install compile storm before a server takes leases.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import build_model, init_decode_state
from repro.serving.blockpool import BlockAllocator, KVHandoff, PrefixCache


# --------------------------------------------------------------------------
# tensor-parallel helpers (no-ops when mesh is None)
# --------------------------------------------------------------------------

def _replicate(mesh, x):
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec()))


def _traced_under_mesh(fn, mesh):
    """Make ``fn`` trace under the serve activation-sharding context, so the
    model's serve-TP constraints (``constrain_replicated`` before every
    cross-shard contraction) bake into its jaxpr.  Prefill produces the
    admission token, which must match the single-device engine bitwise just
    like decode tokens — so prefill traces need the same treatment as the
    step functions.  Identity when there is no mesh."""
    if mesh is None or fn is None:
        return fn
    from repro.runtime.sharding import activation_sharding

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with activation_sharding(mesh, "serve"):
            return fn(*args, **kwargs)
    return wrapped


def _constrain_serve_state(mesh, state):
    """Pin the decode state's output shardings inside a jitted step: pools
    stay head-sharded, tables/scalars stay replicated.  Without this, GSPMD
    is free to re-partition donated outputs between steps, which would make
    the engine's host-side install surgery reshard every tick."""
    from repro.runtime.sharding import serve_state_shardings
    shardings = serve_state_shardings(state, mesh)
    return jax.tree.map(jax.lax.with_sharding_constraint, state, shardings)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted: float = dataclasses.field(default_factory=time.monotonic)
    # filled on completion
    tokens: list = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    done_s: float | None = None
    # disaggregated serving: a prefill-role engine fills this on export;
    # a decode-role engine resumes from it instead of a raw prompt
    handoff: KVHandoff | None = None


@dataclasses.dataclass
class SlotState:
    rid: int = -1                      # -1 == free
    active: bool = False               # decoding (False mid-admission)


@dataclasses.dataclass
class _PrefillJob:
    """A chunked admission in flight: slot is claimed, blocks are mapped,
    ``off`` tracks the next chunk's absolute start position."""
    si: int
    req: Request
    padded: np.ndarray                 # (plen,) int32 left-padded prompt
    plen: int
    off: int                           # == prefix-hit tokens at creation
    row: list                          # physical block ids (prefix + fresh)
    keys: list                         # full-block chain-hash keys
    nhit: int = 0                      # prefix-hit blocks (draft install)


def admit_length(prompt_len: int, max_len: int) -> int:
    """Round a prompt length up to its power-of-two bucket, rejecting
    prompts that cannot decode a single token inside the engine's KV
    budget.  Raises ValueError instead of silently cropping.

    The bucket is capped at ``max_len - 1``: prefill occupies ``plen``
    positions and decode starts writing KV at ``pos == plen``, so a bucket
    equal to ``max_len`` would leave zero decode room (the first decode
    write would clamp onto the last prompt position and corrupt it).
    """
    if prompt_len >= max_len:
        raise ValueError(
            f"prompt length {prompt_len} exceeds the admission cap "
            f"{max_len - 1} (= max_len {max_len} minus the >=1 KV row "
            f"decode needs); truncate the prompt to <= {max_len - 1} "
            f"tokens or build the engine with a larger max_len")
    b = 16
    while b < prompt_len:
        b *= 2
    return min(b, max_len - 1)


def admit_buckets(max_len: int) -> list[int]:
    """Every prompt bucket `admit_length` can produce for this ``max_len``
    (powers of two below the cap, plus the ``max_len - 1`` cap itself).
    `ExecutableRegistry.prefetch` stages a jitted prefill trace for each,
    so no first-request-of-a-bucket ever pays a retrace spike."""
    out = []
    b = 16
    while b < max_len - 1:
        out.append(b)
        b *= 2
    out.append(max_len - 1)
    return out


def prefill_chunk_shapes(max_len: int, block_size: int,
                         chunk: int) -> list[int]:
    """Every chunk length chunked admission can produce: chunk boundaries
    are aligned to absolute multiples of ``chunk``, and a prefix hit can
    start a job at any block boundary, so the set is {min(chunk - off %
    chunk, plen - off)} over all buckets and block-aligned offsets.  Small
    and static — warmable ahead of the first request."""
    shapes = set()
    for plen in admit_buckets(max_len):
        for off in range(0, plen, block_size):
            shapes.add(min(chunk - off % chunk, plen - off))
    return sorted(shapes)


def make_engine_step(bundle, max_len: int, mesh=None):
    """The engine's jitted decode step: decode + argmax + per-slot budget
    debit + done mask, all on device, returning one packed (2, slots) int32
    array.  Module-level so engines built over the SAME bundle/max_len (a
    serve image's factory) share one jit wrapper — which is what lets
    ``ExecutableRegistry.prefetch`` stage the XLA compile before the
    payload's first tick.  The same wrapper serves dense AND paged states
    (different pytree structures trace separately).

    ``mesh`` makes the step SPMD: the body traces under an
    ``activation_sharding`` context (model code then constrains activations
    and dispatches head-sharded Pallas kernels), output state shardings are
    pinned, and ``packed`` is constrained fully replicated so the engine's
    single ``device_get`` stays one transfer — it reads one local shard."""
    def body(params, state, active, budget):
        logits, new_state = bundle.decode(params, state)       # argmax inside
        tok = new_state["token"][:, 0]
        budget = budget - active.astype(jnp.int32)
        done = active & ((budget <= 0) | (new_state["pos"] >= max_len))
        packed = jnp.stack([tok, done.astype(jnp.int32)])      # (2, slots)
        return packed, new_state, active & ~done, budget

    if mesh is None:
        return jax.jit(body, donate_argnums=(1, 2, 3))

    from repro.runtime.sharding import activation_sharding

    def step(params, state, active, budget):
        with activation_sharding(mesh, "serve"):
            packed, new_state, active, budget = body(
                params, state, active, budget)
            new_state = _constrain_serve_state(mesh, new_state)
            packed = _replicate(mesh, packed)
            active = _replicate(mesh, active)
            budget = _replicate(mesh, budget)
        return packed, new_state, active, budget

    return jax.jit(step, donate_argnums=(1, 2, 3))


def make_draft_step(bundle, k: int, max_len: int, mesh=None):
    """The draft half of a speculative step: ``k`` autoregressive draft
    decodes fused into one jitted ``lax.scan`` (one dispatch, zero
    device→host syncs).  The draft writes its KV into its OWN paged pools,
    addressed by the TARGET's block tables — same physical block ids, so
    admission/eviction bookkeeping covers both caches for free.  Returns
    ``(drafts (slots, k) int32, new draft cache)``."""
    def draft(params, cache, token, pos, block_tables):
        state = {"cache": cache, "token": token, "pos": pos,
                 "block_tables": block_tables}

        def body(st, _):
            # clamp the write position: a row whose speculative reach
            # crosses max_len keeps overwriting the last in-bounds
            # position — a block only this row can own (prefix sharing
            # never reaches the final position's block) — and drafts past
            # the end can never be accepted (acceptance clamps at
            # max_len - pos), so live KV is untouched either way
            _, nst = bundle.decode(
                params, {**st, "pos": jnp.minimum(st["pos"], max_len - 1)})
            nst = {**nst, "pos": st["pos"] + 1}
            return nst, nst["token"][:, 0]

        state, toks = jax.lax.scan(body, state, None, length=k)
        return jnp.transpose(toks), state["cache"]

    if mesh is None:
        return jax.jit(draft, donate_argnums=(1,))

    from repro.runtime.sharding import activation_sharding

    def draft_tp(params, cache, token, pos, block_tables):
        with activation_sharding(mesh, "serve"):
            toks, cache = draft(params, cache, token, pos, block_tables)
            cache = _constrain_serve_state(mesh, cache)
            # drafts feed verify device-side; replicated keeps the verify
            # trace free of a gather prologue
            toks = _replicate(mesh, toks)
        return toks, cache

    return jax.jit(draft_tp, donate_argnums=(1,))


def make_verify_step(bundle, max_len: int, k: int, mesh=None):
    """The verify half of a speculative step: ONE batched (k+1)-position
    target forward over [pending token, k drafts], then greedy acceptance
    (truncate at the first draft/target mismatch), budget debit and done
    mask — all on device.  The packed return is a single (k+3, slots)
    int32 array riding the engine's one-transfer-per-step contract:
    row 0 = accepted length ``a`` (0 for free slots), row 1 = done flags,
    rows 2..k+2 = the k+1 target-verified tokens (the host appends the
    first ``a`` of them).  Rejected suffixes need no device work to roll
    back: the host frontier simply does not advance over them, the next
    step's writes land at the committed frontier and overwrite, and
    per-query causal masks hide anything beyond ``pos``."""
    def step(params, state, active, budget, drafts):
        tokens = jnp.concatenate([state["token"], drafts], axis=1)
        logits, new_state = bundle.verify(params, tokens, state)
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, k+1)
        # t_{s+1} is valid iff its input d_s matched the target's own
        # pick t_s at every position up to s: cumprod of the match mask
        match = (preds[:, :k] == drafts).astype(jnp.int32)
        a = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        # clamp to the slot's remaining budget and max_len room (verify
        # probes up to k positions past both; the overshoot is garbage by
        # construction and must not be committed), zero for free slots
        a = jnp.minimum(a, jnp.minimum(budget, max_len - state["pos"]))
        a = jnp.maximum(a, 0) * active.astype(jnp.int32)
        budget = budget - a
        pos = state["pos"] + a
        done = active & ((budget <= 0) | (pos >= max_len))
        token = jnp.take_along_axis(
            preds, jnp.maximum(a - 1, 0)[:, None], axis=1)
        token = jnp.where(active[:, None], token, state["token"])
        new_state = {**new_state, "token": token, "pos": pos}
        packed = jnp.concatenate(
            [a[None], done.astype(jnp.int32)[None], preds.T], axis=0)
        return packed, new_state, active & ~done, budget

    if mesh is None:
        return jax.jit(step, donate_argnums=(1, 2, 3))

    from repro.runtime.sharding import activation_sharding

    def step_tp(params, state, active, budget, drafts):
        with activation_sharding(mesh, "serve"):
            packed, new_state, active, budget = step(
                params, state, active, budget, drafts)
            new_state = _constrain_serve_state(mesh, new_state)
            packed = _replicate(mesh, packed)
            active = _replicate(mesh, active)
            budget = _replicate(mesh, budget)
        return packed, new_state, active, budget

    return jax.jit(step_tp, donate_argnums=(1, 2, 3))


def spec_ineligible_reason(cfg, kv: str) -> str | None:
    """Why an arch cannot run draft-and-verify speculation (None == it
    can).  Mirrors the PR 3 dense fallback: instead of failing, the engine
    records the reason and serves non-speculatively."""
    if cfg.is_encdec:
        return "enc-dec archs have no decoder-only verify path"
    if cfg.is_attention_free or cfg.ssm is not None:
        return ("SSM state rows advance one token at a time and cannot "
                "roll back a rejected speculative suffix")
    if cfg.sliding_window is not None:
        return ("SWA rolling rings overwrite history in place and cannot "
                "roll back a rejected speculative suffix")
    if kv != "paged":
        return ("speculative rollback rides the paged block tables; "
                "kv='dense' has no frontier to truncate")
    return None


def handoff_ineligible_reason(cfg, kv: str) -> str | None:
    """Why an arch cannot serve in a disaggregated role (None == it can).
    The KV handoff moves PAGED BLOCKS between pools, so every per-token
    byte a decode step reads must live inside blocks — per-row state (SSM
    scan rows, SWA rolling rings) has no block id to ship."""
    if cfg.is_encdec:
        return "enc-dec archs do not run the decoder-only serve path"
    if cfg.is_attention_free or cfg.ssm is not None:
        return ("SSM state rows are per-slot, not per-block; they cannot "
                "ride a block-chain handoff")
    if cfg.sliding_window is not None:
        return ("SWA ring rows are per-slot, not per-block; they cannot "
                "ride a block-chain handoff")
    if kv != "paged":
        return "the handoff ships paged blocks; kv='dense' has none"
    return None


class ServeEngine:
    """Continuous-batching engine over a paged KV cache.

    * ``kv`` — "paged" (default for decoder LMs) or "dense" (the seed
      slab layout, kept as the benchmark ablation; forced for enc-dec).
    * ``prefill`` — "oneshot" (whole-bucket prefill at admission) or
      "chunked" (``prefill_chunk``-token chunks interleaved with decode).
    * ``num_blocks`` — pool size; default matches the dense slab's token
      capacity (benchmarks shrink it to measure effective capacity).
    * ``prefix_sharing`` — hash-keyed prompt-prefix block reuse; enabled
      automatically only for architectures whose per-token state lives
      entirely in paged blocks (no SWA ring rows, no SSM state rows).
    * ``admission="wave"`` restores wave-scheduled refills (baseline).

    ``bundle``/``step_fn``/``prefill_fn``/``chunk_fn`` let a serve image's
    factory share one model bundle and its jitted wrappers across engine
    instances (jit caches are per wrapper, so sharing the wrapper is what
    makes a prefetched compile reusable)."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 admission: str = "continuous", kv: str | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefill: str = "oneshot", prefill_chunk: int = 32,
                 prefix_sharing: bool = True, bundle=None, step_fn=None,
                 prefill_fn=None, chunk_fn=None,
                 spec: str = "off", spec_k: int = 4, draft_cfg=None,
                 draft_params=None, draft_bundle=None, draft_fn=None,
                 verify_fn=None, draft_prefill_fn=None, mesh=None,
                 role: str = "unified"):
        assert admission in ("continuous", "wave"), admission
        assert prefill in ("oneshot", "chunked"), prefill
        assert spec in ("off", "draft"), spec
        assert role in ("unified", "prefill", "decode"), role
        self.role = role
        # tensor-parallel serving: the whole engine state lives sharded on
        # `mesh` (params by the serve TP rules, KV pools on their head dim,
        # everything else replicated) and the jitted steps run SPMD.  A
        # 1-device mesh degrades to the single-device engine bit-for-bit.
        self.mesh = mesh
        self.mesh_devices = int(mesh.devices.size) if mesh is not None else 1
        # an arch only pages if some attention layer's per-token state can
        # live in blocks: all-SWA models are pure rolling rings and
        # attention-free models pure SSM state — a pool there would be
        # phantom memory (bookkeeping, telemetry and admission gating over
        # bytes that don't exist), so they fall back to the dense layout
        pages = (not cfg.is_encdec and not cfg.is_attention_free
                 and (cfg.mla is not None or cfg.sliding_window is None))
        if kv is None:
            kv = "paged" if pages else "dense"
        assert kv in ("paged", "dense"), kv
        if kv == "paged" and not pages:
            kv = "dense"
        if role != "unified":
            reason = handoff_ineligible_reason(cfg, kv)
            if reason is not None:
                raise ValueError(
                    f"role={role!r} needs the KV block handoff: {reason}")
        self.cfg = cfg
        if mesh is not None:
            from repro.runtime.sharding import serve_param_shardings
            params = jax.tree.map(
                jax.device_put, params, serve_param_shardings(params, mesh))
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.admission = admission
        self.kv = kv
        self.block_size = block_size
        self.bundle = bundle or build_model(cfg)
        # chunked admission works on both layouts (dense rings append like
        # a T == max_len rolling window) EXCEPT dense MLA, whose chunk
        # path only speaks the paged latent pools
        self.prefill_mode = (
            prefill if (self.bundle.prefill_chunk is not None
                        and (kv == "paged" or cfg.mla is None))
            else "oneshot")
        self.prefill_chunk = prefill_chunk

        if kv == "paged":
            assert prefill_chunk % block_size == 0, (prefill_chunk,
                                                     block_size)
            nb = num_blocks or (slots * (max_len // block_size) + 1)
            self._num_blocks = nb
            self.allocator = BlockAllocator(nb, block_size)
            # prefix reuse needs ALL per-token state inside paged blocks:
            # SWA ring rows and SSM state rows are per-slot and cannot be
            # remapped by block id, so those archs admit without sharing
            prefix_ok = (prefix_sharing and cfg.sliding_window is None
                         and cfg.ssm is None)
            self.prefix = PrefixCache(self.allocator) if prefix_ok else None
            self.state = init_decode_state(
                cfg, slots, max_len, kv="paged", num_blocks=nb,
                block_size=block_size, mesh=mesh)
            self.max_blocks_per_slot = max_len // block_size
        else:
            self.allocator = None
            self.prefix = None
            self.state = init_decode_state(cfg, slots, max_len, mesh=mesh)
            self.max_blocks_per_slot = 0
            self._num_blocks = 0
        self.budget = jnp.zeros((slots,), jnp.int32)          # device-side
        self.active = jnp.zeros((slots,), bool)               # device-side
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.runtime.sharding import serve_state_shardings
            rep = NamedSharding(mesh, PartitionSpec())
            self.budget = jax.device_put(self.budget, rep)
            self.active = jax.device_put(self.active, rep)
            # the target shardings the step functions pin; host-side
            # install surgery is repaired against this (``_ensure_sharded``)
            self._state_shardings = serve_state_shardings(self.state, mesh)
            self._rep_sharding = rep
        else:
            self._state_shardings = None
            self._rep_sharding = None
        self.slot_meta = [SlotState() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self._jobs: deque[_PrefillJob] = deque()
        self.done: dict[int, Request] = {}
        self._live: dict[int, Request] = {}
        # host mirrors (paged bookkeeping + cache-pressure stats)
        self._host_pos = [0] * slots
        self._slot_blocks: list[list[int]] = [[] for _ in range(slots)]
        self._tick_times: list[float] = []     # wall time of decode ticks
        self.steps = 0
        self.idle_slot_steps = 0       # slots with no request during a step
        self.d2h_transfers = 0         # must equal `steps` (one per step)
        self.prefill_chunks = 0
        self.blocked_admissions = 0    # admissions deferred on pool pressure
        self.prefills_exported = 0     # role="prefill": handoffs produced
        self.handoffs_imported = 0     # role="decode": handoffs resumed
        self.prompt_tokens_total = 0
        self.prefix_hit_tokens = 0
        self._kv_util_sum = 0.0
        self.kv_peak_live_tokens = 0
        # speculative-decode accounting (all zero when spec == "off")
        self.spec_drafted = 0          # draft proposals scored by verify
        self.spec_accepted = 0         # of those, committed to requests
        self.tokens_emitted = 0        # total committed tokens (all modes)
        self.draft_time_s = 0.0        # wall time inside the draft chain

        # one compiled decode step for the whole engine lifetime; engine
        # state (decode state + budget + active) is donated every step.
        # A prefill-role engine never decodes (its slots turn over at the
        # handoff export) and a decode-role engine never prefills (its
        # admissions scatter imported blocks), so each drops the other
        # half's executables — the warm-time saving bench_bind measures.
        self._step_fn = (None if role == "prefill"
                         else step_fn or make_engine_step(self.bundle,
                                                          max_len, mesh=mesh))
        # one jitted prefill wrapper; jax re-traces per prompt bucket shape
        self._prefill = (None if role == "decode"
                         else prefill_fn or jax.jit(
                             _traced_under_mesh(self.bundle.prefill, mesh)))
        self._chunk_fn = (
            None if role == "decode"
            else chunk_fn or (
                jax.jit(_traced_under_mesh(self.bundle.prefill_chunk, mesh),
                        donate_argnums=1)
                if self.bundle.prefill_chunk is not None else None))
        if role == "decode":
            self.prefill_mode = "oneshot"    # no chunk path to interleave

        # ---- speculative decoding: draft-and-verify multi-token steps ----
        # the draft model is itself a late-binding decision: a serve image
        # names it in its payload spec and the engine falls back (recorded,
        # not fatal) wherever the arch cannot roll back a rejected suffix
        self.spec = "off"
        self.spec_k = int(spec_k)
        self.spec_fallback_reason = None
        if spec == "draft" and role != "unified":
            # the draft's shadow pools do not ride the handoff, so a
            # resumed request would draft over garbage KV; record the
            # fallback instead of failing, like every other spec gate
            self.spec_fallback_reason = (
                f"role={role}: draft KV does not ride the block handoff")
            spec = "off"
        if spec == "draft":
            reason = spec_ineligible_reason(cfg, self.kv)
            if reason is None and draft_cfg is not None:
                dr = spec_ineligible_reason(draft_cfg, "paged")
                if dr is not None:
                    reason = f"draft arch: {dr}"
                elif draft_cfg.vocab_size != cfg.vocab_size:
                    reason = ("draft vocab differs from target "
                              f"({draft_cfg.vocab_size} vs "
                              f"{cfg.vocab_size}); proposals would not be "
                              "target token ids")
            if reason is not None:
                self.spec_fallback_reason = reason
            else:
                self.spec = "draft"
        if self.spec == "draft":
            self.draft_cfg = draft_cfg or cfg
            # draft_cfg None == self-draft: the target proposes for itself
            # (the upper-bound ablation; every proposal is accepted)
            self.draft_bundle = draft_bundle or (
                self.bundle if draft_cfg is None
                else build_model(self.draft_cfg))
            if draft_params is not None:
                self.draft_params = draft_params
            elif draft_cfg is None:
                self.draft_params = params
            else:
                # fixed seed: every engine in a fleet reconstructs bitwise-
                # identical draft weights, so a requeued request replays the
                # same tokens on whichever server picks it up
                self.draft_params = self.draft_bundle.init(jax.random.key(0))
            if mesh is not None:
                from repro.runtime.sharding import serve_param_shardings
                self.draft_params = jax.tree.map(
                    jax.device_put, self.draft_params,
                    serve_param_shardings(self.draft_params, mesh))
            # the draft's paged pools shadow the target's: same num_blocks,
            # same block_size, addressed through the SAME block-table ids —
            # admission/eviction bookkeeping covers both caches at once
            self._draft_cache = init_decode_state(
                self.draft_cfg, slots, max_len, kv="paged",
                num_blocks=self._num_blocks,
                block_size=block_size, mesh=mesh)["cache"]
            self._draft_fn = draft_fn or make_draft_step(
                self.draft_bundle, self.spec_k, max_len, mesh=mesh)
            self._verify_fn = verify_fn or make_verify_step(
                self.bundle, max_len, self.spec_k, mesh=mesh)
            if mesh is not None:
                from repro.runtime.sharding import serve_state_shardings
                self._draft_shardings = serve_state_shardings(
                    self._draft_cache, mesh)
            else:
                self._draft_shardings = None
            self._draft_prefill = draft_prefill_fn or jax.jit(
                _traced_under_mesh(self.draft_bundle.prefill, mesh))

    # ------------------------------------------------------------------

    @property
    def kv_capacity_tokens(self) -> int:
        """Total KV token capacity the engine's cache memory can hold."""
        if self.kv == "paged":
            return self.allocator.capacity_tokens
        return self.slots * self.max_len

    def submit(self, req: Request):
        """Admit a request.  A prompt that cannot fit the engine's KV
        budget (prompt + at least one generated token within ``max_len``,
        and — paged — a worst-case block reach within the pool) is
        rejected here, explicitly — never silently cropped or deferred
        forever."""
        if req.rid == -1:
            raise ValueError("request id -1 is reserved (the engine's "
                             "free-slot sentinel)")
        if req.handoff is not None:
            if self.role != "decode":
                raise ValueError(
                    f"role={self.role!r} engine cannot import a KV handoff "
                    "(only role='decode' resumes from one)")
            req.handoff.validate_against(self.kv_fingerprint())
            plen = req.handoff.plen
            if plen >= self.max_len:
                raise ValueError(
                    f"handoff bucket {plen} leaves no decode room inside "
                    f"max_len {self.max_len}")
            end_max = min(plen + req.max_new_tokens, self.max_len)
            need = -(-end_max // self.block_size)
            if need > self.allocator.capacity_blocks:
                raise ValueError(
                    f"handoff needs {need} KV blocks (bucket {plen} + "
                    f"budget {req.max_new_tokens}) but the pool holds "
                    f"{self.allocator.capacity_blocks}")
            self.queue.append(req)
            return
        if self.role == "decode":
            raise ValueError(
                "role='decode' engine only accepts handoff requests; "
                "route raw prompts to the prefill pool")
        plen = admit_length(len(req.prompt), self.max_len)
        if self.kv == "paged":
            # a prefill-role engine maps only the prompt's blocks — its
            # slots turn over at the export, so the decode budget's reach
            # is the DECODE pool's problem
            end_max = (plen if self.role == "prefill"
                       else min(plen + req.max_new_tokens, self.max_len))
            need = -(-end_max // self.block_size)
            if need > self.allocator.capacity_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks (prompt bucket {plen} "
                    f"+ budget {req.max_new_tokens}) but the pool holds "
                    f"{self.allocator.capacity_blocks}; admission could "
                    f"never succeed — shrink the request or grow "
                    f"num_blocks")
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        return admit_length(n, self.max_len)

    # ------------------------------------------------------------------
    # slot-granular admission
    # ------------------------------------------------------------------

    def _admit(self):
        """Fill free slots from the queue.  Continuous mode refills any free
        slot immediately; wave mode (baseline) only refills once ALL slots
        have drained.  Paged admission can defer on pool pressure (the
        request stays queued; `blocked_admissions` counts the stall)."""
        free = [i for i, m in enumerate(self.slot_meta) if m.rid == -1]
        if not free or not self.queue:
            return
        if self.admission == "wave" and len(free) < self.slots:
            return
        for si in free:
            if not self.queue:
                break
            if not self._admit_into(si, self.queue[0]):
                break                              # pool pressure: retry later
            self.queue.popleft()

    def _admit_into(self, si: int, req: Request) -> bool:
        """Begin admission of one request into batch row `si` while the
        other slots' decode state stays untouched.  Returns False when the
        paged pool cannot hold the request yet."""
        if req.handoff is not None:
            return self._admit_handoff_into(si, req)
        plen = self._bucket(len(req.prompt))
        bs = self.block_size
        padded = np.zeros((plen,), np.int32)
        padded[-len(req.prompt):] = req.prompt                # left-pad
        row, keys, hit, shareable = [], [], [], 0
        if self.kv == "paged":
            end_max = (plen if self.role == "prefill"
                       else min(plen + req.max_new_tokens, self.max_len))
            total_blocks = -(-end_max // bs)
            n_full = plen // bs
            # cap sharing below the last prompt position so admission
            # always has >= 1 chunk/prefill position to produce logits
            shareable = min(n_full, (plen - 1) // bs)
            keys = (PrefixCache.block_keys(padded, bs, n_full)
                    if self.prefix is not None else [])
            hit = self.prefix.match(keys[:shareable]) if self.prefix else []
            need = total_blocks - len(hit)
            if self.allocator.available_blocks < need:
                if self.prefix is not None:
                    self.prefix.evict_unreferenced(
                        need - self.allocator.available_blocks)
                if self.allocator.available_blocks < need:
                    for bid in hit:                # undo the match refs
                        self.allocator.free(bid)
                    self.blocked_admissions += 1
                    return False
            # map the request's WHOLE reach (prompt bucket + budget,
            # capped at max_len) now: the block table is then written once
            # per admission and the decode loop never touches it
            row = hit + [self.allocator.alloc() for _ in range(need)]
            self._slot_blocks[si] = list(row)
            self.prefix_hit_tokens += len(hit) * bs
        nhit = len(hit)
        self.prompt_tokens_total += plen
        self.slot_meta[si].rid = req.rid
        self._live[req.rid] = req

        if self.prefill_mode == "chunked":
            self._zero_ssm_rows(si)
            self._jobs.append(_PrefillJob(
                si=si, req=req, padded=padded, plen=plen,
                off=nhit * bs, row=row, keys=keys, nhit=nhit))
            return True

        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(padded[None])})
        nxt = int(jnp.argmax(logits[0, -1]))                  # admission-time
        if self.kv == "paged":
            self.state = _install_slot_paged(
                self.state, cache, si, plen, nxt, row, nhit, bs)
            self._publish_prefix(keys, row, nhit, shareable)
            self._install_draft(padded, row, nhit)
        else:
            self.state = _install_slot(self.state, cache, si, plen, nxt)
        if self.role == "prefill":
            self._finish_prefill_export(si, req, plen, nxt, padded, keys)
        else:
            self._finish_admission(si, req, plen, nxt)
        return True

    def _finish_admission(self, si: int, req: Request, plen: int, nxt: int):
        m = self.slot_meta[si]
        m.rid = req.rid
        m.active = True
        self.active = self.active.at[si].set(True)
        self.budget = self.budget.at[si].set(req.max_new_tokens)
        self._host_pos[si] = plen
        req.tokens.append(nxt)
        req.first_token_s = time.monotonic() - req.submitted
        self._live[req.rid] = req

    # ------------------------------------------------------------------
    # disaggregated serving: KV block export (prefill) / import (decode)
    # ------------------------------------------------------------------

    def kv_fingerprint(self) -> tuple:
        """Pool-layout identity a handoff must match: block size plus each
        layer's paged keys with their per-block shapes and dtypes.  Two
        engines agree iff a block gathered from one scatters into the
        other unchanged — same arch family, head layout and KV dtype."""
        assert self.kv == "paged", "fingerprint is a paged-pool property"
        layers = tuple(
            tuple(sorted((k, v.shape[:1] + v.shape[2:], str(v.dtype))
                         for k, v in leaf.items()
                         if k in self._PAGED_KEYS))
            for leaf in self.state["cache"])
        return (self.block_size, layers)

    def _finish_prefill_export(self, si: int, req: Request, plen: int,
                               nxt: int, padded: np.ndarray, keys: list):
        """Prefill-role completion: gather the slot's prompt block chain
        into contiguous host buffers, attach the chain-hash keys and the
        admission token, and finish the request — the slot and its blocks
        turn over immediately, which is what lets a prefill pool drain
        prompts at prefill service rate instead of holding slots for the
        whole decode."""
        bs = self.block_size
        n_pb = -(-plen // bs)
        n_full = plen // bs
        if not keys:
            # prefix sharing may be off here, but the DECODE pool still
            # wants the keys for republish — they only depend on the
            # padded tokens, not on this engine's cache
            keys = PrefixCache.block_keys(padded, bs, n_full)
        row = self._slot_blocks[si]
        bufs = _gather_blocks(self.state["cache"], row[:n_pb],
                              self._PAGED_KEYS)
        req.handoff = KVHandoff(
            rid=req.rid, prompt=np.asarray(req.prompt, np.int32),
            plen=plen, first_token=nxt, max_new_tokens=req.max_new_tokens,
            block_hashes=tuple(keys), fingerprint=self.kv_fingerprint(),
            blocks=bufs)
        now = time.monotonic()
        req.tokens.append(nxt)
        req.first_token_s = now - req.submitted
        req.done_s = now - req.submitted
        self.prefills_exported += 1
        self._live.pop(req.rid, None)
        self.done[req.rid] = req
        self._evict_slot(si)

    def _admit_handoff_into(self, si: int, req: Request) -> bool:
        """Decode-role admission: scatter an imported block chain into
        this pool and resume at the first generated token.  The installed
        slot state (``pos = plen``, ``token = first_token``, prompt KV in
        rows ``0..plen-1``) is EXACTLY what `_finish_admission` leaves
        behind on a unified engine, so the greedy stream continues
        bitwise identically.  Prefix-hit blocks are skipped in the
        scatter and fresh full blocks are republished under the handoff's
        own keys — sharing crosses the pool boundary."""
        h = req.handoff
        bs = self.block_size
        plen = h.plen
        end_max = min(plen + req.max_new_tokens, self.max_len)
        total_blocks = -(-end_max // bs)
        n_pb = -(-plen // bs)
        n_full = plen // bs
        shareable = min(n_full, (plen - 1) // bs)
        keys = list(h.block_hashes)
        hit = self.prefix.match(keys[:shareable]) if self.prefix else []
        need = total_blocks - len(hit)
        if self.allocator.available_blocks < need:
            if self.prefix is not None:
                self.prefix.evict_unreferenced(
                    need - self.allocator.available_blocks)
            if self.allocator.available_blocks < need:
                for bid in hit:                    # undo the match refs
                    self.allocator.free(bid)
                self.blocked_admissions += 1
                return False
        row = hit + [self.allocator.alloc() for _ in range(need)]
        self._slot_blocks[si] = list(row)
        nhit = len(hit)
        self.prefix_hit_tokens += nhit * bs
        self.prompt_tokens_total += plen
        self.slot_meta[si].rid = req.rid
        self.state = _import_blocks_paged(
            self.state, h.blocks, si, plen, h.first_token, row, nhit, bs)
        self._publish_prefix(keys, row, nhit, shareable)
        self.handoffs_imported += 1
        m = self.slot_meta[si]
        m.active = True
        self.active = self.active.at[si].set(True)
        self.budget = self.budget.at[si].set(req.max_new_tokens)
        self._host_pos[si] = plen
        if not req.tokens:
            # the stream already starts with prefill's admission token;
            # a replayed import (requeue-from-handoff) re-appends it on
            # the fresh Request the dispatcher rebuilt
            req.tokens.append(h.first_token)
        req.first_token_s = time.monotonic() - req.submitted
        self._live[req.rid] = req
        return True

    def _dummy_handoff(self, plen: int) -> KVHandoff:
        """A zero-KV handoff shaped exactly like a real one for bucket
        ``plen`` — `warm_install` feeds these through the import scatter
        so a decode server absorbs its compile storm before taking
        leases."""
        bs = self.block_size
        n_pb = -(-plen // bs)
        n_full = plen // bs
        prompt = (np.arange(max(plen - 1, 1)) % self.cfg.vocab_size).astype(
            np.int32)
        padded = np.zeros((plen,), np.int32)
        padded[-len(prompt):] = prompt
        blocks = [
            {k: np.zeros(v.shape[:1] + (n_pb,) + v.shape[2:], v.dtype)
             for k, v in leaf.items() if k in self._PAGED_KEYS}
            for leaf in self.state["cache"]]
        return KVHandoff(
            rid=-2, prompt=prompt, plen=plen, first_token=0,
            max_new_tokens=1,
            block_hashes=tuple(PrefixCache.block_keys(padded, bs, n_full)),
            fingerprint=self.kv_fingerprint(), blocks=blocks)

    def _publish_prefix(self, keys, row, nhit: int, shareable: int):
        """Register freshly-filled full blocks, capped at the MATCHABLE
        range: the block holding the last prompt position can never be
        returned by `match` (admission must keep >= 1 position to compute
        logits), so publishing it would only pin pool capacity."""
        if self.prefix is None:
            return
        for j in range(nhit, shareable):
            self.prefix.publish(keys[j], row[j])

    def _zero_ssm_rows(self, si: int):
        """Chunked prefill scans SSM layers from the row's cached state, so
        a new request must start that row from zeros (paged/ring attention
        rows need no reset: their stale entries are masked or overwritten)."""
        if self.cfg.ssm is None:
            return
        new_cache = []
        for leaf in self.state["cache"]:
            if "conv" in leaf:
                leaf = {k: v.at[:, si].set(jnp.zeros_like(v[:, si]))
                        for k, v in leaf.items()}
            new_cache.append(leaf)
        self.state = {**self.state, "cache": new_cache}

    def _install_draft(self, padded, row, nhit: int):
        """Prompt-prefill the DRAFT model for a freshly admitted request
        and scatter its KV into the draft pools at the same physical block
        ids the target admission mapped.  Prefix-hit blocks are skipped:
        draft prefill is deterministic, so the admission that published a
        shared block already left bit-identical draft KV in the shadow
        pool — prefix reuse covers both caches for free."""
        if self.spec != "draft":
            return
        _, dcache = self._draft_prefill(
            self.draft_params, {"tokens": jnp.asarray(padded[None])})
        self._draft_cache = _install_draft_paged(
            self._draft_cache, dcache, row, nhit, self.block_size)

    # ------------------------------------------------------------------
    # chunked prefill: at most ONE chunk per engine tick
    # ------------------------------------------------------------------

    def _prefill_tick(self):
        if not self._jobs:
            return
        job = self._jobs[0]
        # chunk boundaries are aligned to absolute multiples of the chunk
        # size, so the set of chunk shapes stays closed under prefix-hit
        # offsets (see `prefill_chunk_shapes`) — no mid-serve retraces
        C = min(self.prefill_chunk - job.off % self.prefill_chunk,
                job.plen - job.off)
        toks = jnp.asarray(job.padded[None, job.off:job.off + C])
        # dense chunked admission (all-SWA / SSM archs) has no blocks: the
        # table-row arg is a 1-wide dummy no cache leaf ever indexes
        row_arr = np.zeros((max(self.max_blocks_per_slot, 1),), np.int32)
        row_arr[:len(job.row)] = job.row
        logits, self.state = self._chunk_fn(
            self.params, self.state, toks, jnp.asarray(row_arr),
            jnp.int32(job.si), jnp.int32(job.off))
        self.prefill_chunks += 1
        job.off += C
        if job.off < job.plen:
            return
        # last chunk landed: install the block-table row on device and
        # flip the slot to decoding (unified) or export the handoff and
        # turn the slot over (prefill role)
        nxt = int(jnp.argmax(logits[0]))
        if self.kv == "paged":
            self.state["block_tables"] = (
                self.state["block_tables"].at[job.si].set(
                    jnp.asarray(row_arr)))
        self.state["token"] = self.state["token"].at[job.si, 0].set(nxt)
        self.state["pos"] = self.state["pos"].at[job.si].set(job.plen)
        # the DRAFT prompt KV lands in one shot on the final chunk's tick:
        # the draft is orders of magnitude smaller than the target, so its
        # whole-bucket prefill costs less than one more target chunk would
        self._install_draft(job.padded, job.row, job.nhit)
        self._publish_prefix(
            job.keys, job.row, 0,
            min(job.plen // self.block_size,
                (job.plen - 1) // self.block_size))
        if self.role == "prefill":
            self._finish_prefill_export(job.si, job.req, job.plen, nxt,
                                        job.padded, job.keys)
        else:
            self._finish_admission(job.si, job.req, job.plen, nxt)
        self._jobs.popleft()

    # ------------------------------------------------------------------

    _PAGED_KEYS = ("kp", "vp", "ckvp", "kropep")

    def _guard_rows(self):
        """Snapshot the PER-ROW (non-paged) cache leaves — SSM state rows,
        SWA ring rows — of every mid-admission slot.  The scratch block
        only protects paged pools from free-slot garbage writes; the
        batched decode step advances per-row state unconditionally, which
        would corrupt a half-prefilled request between chunks.  Restored
        right after the step (`_restore_rows`)."""
        sis = sorted({job.si for job in self._jobs})
        if not sis:
            return None
        idx = jnp.asarray(sis)
        snap = [(li, k, v[:, idx])
                for li, leaf in enumerate(self.state["cache"])
                for k, v in leaf.items() if k not in self._PAGED_KEYS]
        return (idx, snap) if snap else None

    def _restore_rows(self, guard):
        idx, snap = guard
        cache = [dict(leaf) for leaf in self.state["cache"]]
        for li, k, v in snap:
            cache[li][k] = cache[li][k].at[:, idx].set(v)
        self.state = {**self.state, "cache": cache}

    def _evict_slot(self, si: int):
        # Frontier truncation doubles as the speculative rollback path: a
        # cancel or eviction can land MID-VERIFY, with draft/verify KV
        # written up to spec_k positions past the committed frontier (in
        # BOTH the target and the shadow draft pools).  Speculation never
        # allocates — admission maps the request's whole reach — so every
        # frontier extension lives in blocks this row already owns; freeing
        # `_slot_blocks` releases all of them and zeroing the device table
        # row makes the stale entries unreachable.  Refcounts therefore
        # balance exactly one free per admission-time alloc/share, with no
        # speculative remainder to leak or double-free (the cancel-mid-
        # verify churn test asserts the allocator returns to prefix-only).
        m = self.slot_meta[si]
        if self.kv == "paged":
            for bid in self._slot_blocks[si]:
                self.allocator.free(bid)
            self._slot_blocks[si] = []
            self.state["block_tables"] = (
                self.state["block_tables"].at[si].set(0))
        m.rid = -1
        m.active = False
        self._host_pos[si] = 0

    # ------------------------------------------------------------------
    # per-request drain/export: the fleet dispatcher's re-dispatch hooks
    # ------------------------------------------------------------------

    def cancel(self, rid: int) -> Request | None:
        """Remove request ``rid`` wherever it lives — the submit queue, a
        mid-admission chunked-prefill job, or a live decode slot — and
        return it for re-dispatch (tokens produced so far intact).  Evicting
        a slot returns every KV block it owned; the freed slot refills on
        the next tick.  Returns None when the engine does not hold ``rid``
        (already completed or never admitted here)."""
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                del self.queue[i]
                return r
        # mid-admission jobs claim a slot + blocks before they decode, and
        # must be checked BEFORE slot_meta so the job entry dies with them
        for j, job in enumerate(self._jobs):
            if job.req.rid == rid:
                del self._jobs[j]
                self._live.pop(rid, None)
                self._evict_slot(job.si)
                return job.req
        for si, m in enumerate(self.slot_meta):
            if m.rid == rid:
                req = self._live.pop(rid, None)
                self.active = self.active.at[si].set(False)
                self._evict_slot(si)
                return req
        return None

    def drain_requests(self) -> list[Request]:
        """Evict EVERY queued / mid-admission / decoding request and return
        them for re-dispatch on another engine (replay-from-prompt).  Used
        when a serving payload gives its remaining work back instead of
        letting the leases expire."""
        rids = dict.fromkeys(
            [r.rid for r in self.queue]
            + [j.req.rid for j in self._jobs]
            + [m.rid for m in self.slot_meta if m.rid != -1])
        out = []
        for rid in rids:
            req = self.cancel(rid)
            if req is not None:
                out.append(req)
        return out

    def _ensure_sharded(self):
        """Repair sharding drift before a mesh step: the eager host-side
        install/evict surgery (`.at[].set`, block scatters) can hand back
        leaves whose placement no longer matches the step's pinned
        shardings, which would force GSPMD to reshard (or jit to re-trace)
        every tick.  The `.sharding` comparison is pure host metadata —
        leaves already in place cost nothing."""
        if self.mesh is None:
            return

        def fix(x, s):
            return x if getattr(x, "sharding", None) == s else \
                jax.device_put(x, s)

        self.state = jax.tree.map(fix, self.state, self._state_shardings)
        rep = self._rep_sharding
        self.active = fix(self.active, rep)
        self.budget = fix(self.budget, rep)
        if self.spec == "draft":
            self._draft_cache = jax.tree.map(
                fix, self._draft_cache, self._draft_shardings)

    def step(self) -> int:
        """One engine iteration: admit into free slots, advance at most one
        prefill chunk, then one batched decode step.  Returns the number of
        live slots decoded (0 when no slot is decoding — an idle or
        admission-only tick is not a decode step)."""
        t_tick = time.monotonic()
        self._admit()
        self._prefill_tick()
        actives = [si for si, m in enumerate(self.slot_meta) if m.active]
        if not actives:
            return 0
        self._ensure_sharded()
        guard = self._guard_rows() if self._jobs else None
        if self.spec == "draft":
            # draft chain: k small-model decodes in one dispatch, writing
            # into the shadow pools.  block_until_ready is a host SYNC, not
            # a transfer — the drafts stay device-resident and feed verify
            # directly; only the packed verify result crosses to the host.
            t_draft = time.monotonic()
            drafts, self._draft_cache = self._draft_fn(
                self.draft_params, self._draft_cache, self.state["token"],
                self.state["pos"], self.state["block_tables"])
            jax.block_until_ready(drafts)
            self.draft_time_s += time.monotonic() - t_draft
            packed, self.state, self.active, self.budget = self._verify_fn(
                self.params, self.state, self.active, self.budget, drafts)
        else:
            packed, self.state, self.active, self.budget = self._step_fn(
                self.params, self.state, self.active, self.budget)
        if guard is not None:
            self._restore_rows(guard)
        self.steps += 1
        self.idle_slot_steps += self.slots - len(actives)
        # lint: allow[one-transfer] -- THE single whitelisted device→host transfer per step (d2h_transfers counts it)
        out = jax.device_get(packed)
        self.d2h_transfers += 1
        if self.spec == "draft":
            acc, dones, tok_rows = out[0], out[1], out[2:]
        else:
            acc = np.ones((self.slots,), np.int64)
            toks, dones = out[0], out[1]
            tok_rows = toks[None]
        emitted = 0
        for si in actives:
            self._host_pos[si] += int(acc[si])
            emitted += int(acc[si])
        self._sample_kv_pressure()         # before evictions, as ever
        now = time.monotonic()
        for si in actives:
            meta = self.slot_meta[si]
            req = self._live[meta.rid]
            req.tokens.extend(int(tok_rows[s][si])
                              for s in range(int(acc[si])))
            if dones[si]:
                req.done_s = now - req.submitted
                self.done[req.rid] = req
                del self._live[meta.rid]
                self._evict_slot(si)
        if self.spec == "draft":
            self.spec_drafted += self.spec_k * len(actives)
            # of each slot's a committed tokens, a-1 were draft proposals
            # the target ratified; the last is the target's own bonus token
            self.spec_accepted += emitted - len(actives)
        self.tokens_emitted += emitted
        # the latency every decoding slot experienced this tick — admission
        # work included, which is exactly what the chunked-prefill
        # interleave rule bounds (<= one chunk per tick)
        self._tick_times.append(time.monotonic() - t_tick)
        return emitted

    def warm_admission(self):
        """Stage every admission executable ahead of the first request:
        one jitted prefill trace per admit-length bucket, and (chunked
        mode) one chunk trace per possible chunk shape.  Chunk warming
        targets an all-scratch block-table row, so its writes land in the
        garbage block and no live state is disturbed.  Engines built by a
        serve image's factory share these jit wrappers, so a registry
        prefetch pays this once for every engine the image ever builds."""
        assert not self._live and not self._jobs, "warm on an idle engine"
        if self.role == "decode":
            return                     # no prefill executables to stage
        for pb in admit_buckets(self.max_len):
            logits, _ = self._prefill(
                self.params, {"tokens": jnp.zeros((1, pb), jnp.int32)})
            jax.block_until_ready(logits)
            if self.spec == "draft":
                # the draft bundle prefills once per admission too — stage
                # its trace for every bucket alongside the target's
                dlogits, _ = self._draft_prefill(
                    self.draft_params,
                    {"tokens": jnp.zeros((1, pb), jnp.int32)})
                jax.block_until_ready(dlogits)
        if self.prefill_mode == "chunked" and self._chunk_fn is not None:
            row = jnp.zeros((max(self.max_blocks_per_slot, 1),), jnp.int32)
            for C in prefill_chunk_shapes(self.max_len, self.block_size,
                                          self.prefill_chunk):
                logits, self.state = self._chunk_fn(
                    self.params, self.state,
                    jnp.zeros((1, C), jnp.int32), row,
                    jnp.int32(0), jnp.int32(0))
                jax.block_until_ready(logits)
            if self.cfg.ssm is not None:
                self._zero_ssm_rows(0)         # undo the warm's row scribble

    def warm_install(self):
        """Run one REAL admission + decode + eviction per admit bucket over
        dummy requests, then reset.  ``warm_admission`` stages the jitted
        prefill/chunk/step executables, but the admission INSTALL path
        (cache-row merge, paged block scatter, block-table writes, the
        packed-step unpack) is eager-dispatched — dozens of first-use op
        compiles that would otherwise land on the first live request's
        tick.  A fleet server must absorb that storm before taking leases:
        one stalled tick longer than the lease TTL makes the pool requeue
        everything the server just fetched."""
        assert not self._live and not self.queue and not self._jobs, \
            "warm on an idle engine"
        for i, pb in enumerate(admit_buckets(self.max_len)):
            try:
                # rid -1 is the free-slot sentinel and rejected by submit;
                # dummies start at -2
                if self.role == "decode":
                    # a decode-role engine admits via the import scatter,
                    # so its storm is warmed with synthetic handoffs
                    h = self._dummy_handoff(pb)
                    self.submit(Request(rid=-2 - i, prompt=h.prompt,
                                        max_new_tokens=1, handoff=h))
                else:
                    self.submit(Request(
                        rid=-2 - i,
                        prompt=(np.arange(pb) % self.cfg.vocab_size).astype(
                            np.int32),
                        max_new_tokens=1))
            except ValueError:
                continue                   # bucket exceeds this pool's reach
        self.run()
        if self.prefix is not None:
            # flush the dummies' published blocks: real prompts never match
            # the synthetic patterns, so leaving them cached would only pin
            # pool capacity and skew utilization stats from the first tick
            self.prefix.evict_unreferenced(self.allocator.capacity_blocks)
        self.reset_metrics()               # also drops the dummy results

    def block_leaks(self) -> int:
        """KV block-pool leak audit for an IDLE engine (nothing live,
        queued, or mid-admission): evicts the prefix cache's published
        (but unreferenced) blocks and returns how many pool blocks remain
        allocated — which must be zero if every admit/cancel/rollback path
        balanced its refcounts.  Chaos drills call this after drain on
        every server: hedged-loser cancels and stall revocations are
        exactly the paths that could strand a block."""
        if self.kv != "paged":
            return 0
        assert not self._live and not self.queue and not self._jobs, \
            "block_leaks() on a busy engine"
        if self.prefix is not None:
            self.prefix.evict_unreferenced(self.allocator.capacity_blocks)
        return self.allocator.allocated_blocks

    def kv_pool_bytes(self) -> dict:
        """KV cache memory: logical total and the per-device (local shard)
        footprint.  On a 1xN mesh the head-sharded pools put ~1/N of the
        pool bytes on each device — the capacity headroom TP buys."""
        total = local = 0
        for leaf in jax.tree.leaves(self.state["cache"]):
            total += int(leaf.nbytes)
            shards = getattr(leaf, "addressable_shards", None)
            local += int(shards[0].data.nbytes) if shards \
                else int(leaf.nbytes)
        return {"kv_pool_bytes": total, "kv_pool_bytes_per_device": local}

    def kv_pressure(self) -> dict:
        """Instantaneous cache-pressure sample for heartbeat telemetry:
        live/allocated RIGHT NOW (the `_stats` dict reports the mean over
        decode steps instead), so a pilot monitor sees a late-run pressure
        spike the moment it happens."""
        live = sum(self._host_pos[si]
                   for si, m in enumerate(self.slot_meta) if m.active)
        if self.kv == "paged":
            allocated = self.allocator.allocated_blocks * self.block_size
        else:
            allocated = self.slots * self.max_len
        return {
            "kv": self.kv,
            "role": self.role,
            "prefills_exported": self.prefills_exported,
            "handoffs_imported": self.handoffs_imported,
            "kv_memory_utilization": live / allocated if allocated else 0.0,
            "kv_live_tokens": live,
            "kv_peak_live_tokens": self.kv_peak_live_tokens,
            "kv_capacity_tokens": self.kv_capacity_tokens,
            # capacity accounting for the pool/autoscaler: a mesh-bound
            # server is ONE unit of `slots` capacity however many devices
            # back it; kv_capacity_tokens above is already per-mesh (the
            # pools are sharded, not replicated, across the mesh)
            "slots": self.slots,
            "mesh_devices": self.mesh_devices,
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / self.prompt_tokens_total
                                if self.prompt_tokens_total else 0.0),
            # speculative effectiveness, live: the autoscaler reads these
            # to convert nominal slot capacity into EFFECTIVE token/step
            # capacity (a pool decoding 3 tokens/step needs fewer pilots)
            "acceptance_rate": (self.spec_accepted / self.spec_drafted
                                if self.spec_drafted else 0.0),
            "tokens_per_step": (self.tokens_emitted / self.steps
                                if self.steps else 0.0),
        }

    def _sample_kv_pressure(self):
        live = sum(self._host_pos[si]
                   for si, m in enumerate(self.slot_meta) if m.active)
        if self.kv == "paged":
            allocated = self.allocator.allocated_blocks * self.block_size
        else:
            allocated = self.slots * self.max_len
        if allocated:
            self._kv_util_sum += live / allocated
        self.kv_peak_live_tokens = max(self.kv_peak_live_tokens, live)

    # ------------------------------------------------------------------

    def run(self, *, max_steps: int = 10_000) -> dict:
        t0 = time.monotonic()
        decoded = 0
        ticks = 0
        # prefill-role engines never take a decode step, so the safety
        # valve also counts raw ticks (admission/export work per tick)
        while ((self.queue or self._live or self._jobs)
               and self.steps < max_steps and ticks < max_steps):
            decoded += self.step()
            ticks += 1
        return self._stats(decoded, time.monotonic() - t0)

    def run_trace(self, trace, *, max_ticks: int = 100_000,
                  on_tick=None) -> dict:
        """Drive the engine from a request *trace* with staggered arrivals.

        ``trace`` is a list of JSON-able dicts (the startup-spec format the
        pilot system ships to a serve payload):
        ``{"rid", "prompt": [ints], "max_new_tokens", "at_step"}`` — the
        request becomes visible to admission at tick ``at_step``.  Idle
        ticks (waiting for an arrival) advance time but are not decode
        steps.

        ``on_tick(tick, step_seconds)`` (optional) runs after every tick —
        the wrapper's heartbeat/stop hook; returning False aborts the run.
        """
        pending = sorted(enumerate(trace),
                         key=lambda ie: int(ie[1].get("at_step", 0)))
        t0 = time.monotonic()
        decoded, tick, i = 0, 0, 0
        while i < len(pending) or self.queue or self._live or self._jobs:
            while i < len(pending) and int(pending[i][1].get("at_step", 0)) <= tick:
                idx, e = pending[i]
                i += 1
                self.submit(Request(
                    rid=int(e.get("rid", idx)),
                    prompt=np.asarray(e["prompt"], np.int32),
                    max_new_tokens=int(e.get("max_new_tokens", 16))))
            t_step = time.monotonic()
            decoded += self.step()
            tick += 1
            if on_tick is not None and on_tick(
                    tick, time.monotonic() - t_step) is False:
                break
            if tick >= max_ticks:
                break
        return self._stats(decoded, time.monotonic() - t0)

    def _stats(self, decoded: int, wall: float) -> dict:
        # occupancy, not throughput: with speculation a slot can commit
        # several tokens per step, so utilization counts slot-steps that
        # had a live request (identical to decoded/(steps*slots) when
        # spec == "off", where every live slot-step emits exactly one)
        denom = self.steps * self.slots
        util = (denom - self.idle_slot_steps) / denom if self.steps else 0.0
        ttfts = [r.first_token_s for r in self.done.values()
                 if r.first_token_s is not None]
        tpots = [(r.done_s - r.first_token_s) / max(1, len(r.tokens) - 1)
                 for r in self.done.values()
                 if r.done_s is not None and r.first_token_s is not None
                 and len(r.tokens) > 1]
        pct = lambda v, q: float(np.percentile(v, q)) if v else None
        return {
            "completed": len(self.done),
            "role": self.role,
            "prefills_exported": self.prefills_exported,
            "handoffs_imported": self.handoffs_imported,
            "decode_steps": self.steps,
            "tokens_decoded": decoded,
            "slot_utilization": util,
            "idle_slot_steps": self.idle_slot_steps,
            "d2h_transfers": self.d2h_transfers,
            "wall_s": wall,
            "tok_per_s": decoded / wall if wall else 0.0,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p99_s": pct(ttfts, 99),
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p99_s": pct(tpots, 99),
            # inter-token latency: wall time of each decode TICK (admission
            # work included) — the stall a running slot actually observes;
            # stop-the-world prefill shows up in the p99
            "itl_p50_s": pct(self._tick_times, 50),
            "itl_p99_s": pct(self._tick_times, 99),
            # cache pressure (live tokens / allocated cache tokens, mean
            # over decode steps) + prefix-cache effectiveness
            "kv": self.kv,
            "kv_memory_utilization": (self._kv_util_sum / self.steps
                                      if self.steps else 0.0),
            "kv_peak_live_tokens": self.kv_peak_live_tokens,
            "kv_capacity_tokens": self.kv_capacity_tokens,
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / self.prompt_tokens_total
                                if self.prompt_tokens_total else 0.0),
            "prefill_chunks": self.prefill_chunks,
            "blocked_admissions": self.blocked_admissions,
            # speculative decoding
            "spec": self.spec,
            "spec_k": self.spec_k if self.spec != "off" else 0,
            "spec_fallback_reason": self.spec_fallback_reason,
            "acceptance_rate": (self.spec_accepted / self.spec_drafted
                                if self.spec_drafted else 0.0),
            "tokens_per_step": decoded / self.steps if self.steps else 0.0,
            "draft_overhead_s": self.draft_time_s,
            # tensor-parallel footprint: shape None == single device;
            # per-device bytes < total is the memory headroom TP buys
            "mesh_shape": (tuple(self.mesh.devices.shape)
                           if self.mesh is not None else None),
            "mesh_devices": self.mesh_devices,
            "slots": self.slots,
            **self.kv_pool_bytes(),
        }

    def reset_metrics(self):
        """Zero the counters/results between benchmark phases (e.g. after a
        jit-warmup run) without touching compiled functions or slot state."""
        assert not self._live and not self.queue and not self._jobs, \
            "engine still has work"
        self.steps = 0
        self.idle_slot_steps = 0
        self.d2h_transfers = 0
        self.prefill_chunks = 0
        self.blocked_admissions = 0
        self.prefills_exported = 0
        self.handoffs_imported = 0
        self.prompt_tokens_total = 0
        self.prefix_hit_tokens = 0
        self._kv_util_sum = 0.0
        self.kv_peak_live_tokens = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.tokens_emitted = 0
        self.draft_time_s = 0.0
        self._tick_times = []
        if self.prefix is not None:
            self.prefix.lookups = 0
            self.prefix.hits = 0
        self.done.clear()


# --------------------------------------------------------------------------


def _install_slot(state, prefill_cache, slot: int, plen: int, next_token: int):
    """Copy one prefilled request's cache rows into batch row `slot` of the
    engine's shared DENSE decode state and reset that row's position to
    `plen`.  All LM cache leaves are stacked (n_groups/L, B, ...), so the
    batch dim is 1 everywhere."""
    new_cache = jax.tree.map(
        lambda dst, src: _merge_row(dst, src, slot),
        state["cache"], prefill_cache)
    token = state["token"].at[slot, 0].set(next_token)
    pos = state["pos"].at[slot].set(plen)
    return {"cache": new_cache, "token": token, "pos": pos}


def _merge_row(dst, src, slot):
    """Write prefill leaf `src` (groups, 1, T', ...) into row `slot` of the
    engine leaf `dst` (groups, B, T, ...)."""
    src_b = jnp.moveaxis(src, 1, 0)[0]           # drop batch (=1)
    dst_b = jnp.moveaxis(dst, 1, 0)              # (B, groups, ...)
    dst_b = dst_b.at[slot].set(
        _fit_rows(src_b, dst_b.shape[1:]).astype(dst.dtype))
    return jnp.moveaxis(dst_b, 0, 1)


def _install_slot_paged(state, prefill_cache, slot: int, plen: int,
                        next_token: int, row: list, nhit: int,
                        block_size: int):
    """Install a one-shot prefill into the PAGED decode state: scatter the
    dense prefill rows into the slot's freshly-allocated blocks (prefix-hit
    blocks already hold bit-identical content and are NOT written — that is
    the copy-free part of prefix reuse), write per-row leaves (SWA rings,
    SSM state) into batch row `slot`, and map the block-table row."""
    paged_keys = {"kp": "k", "vp": "v", "ckvp": "ckv", "kropep": "krope"}
    new_cache = []
    for st_leaf, pf_leaf in zip(state["cache"], prefill_cache):
        out = {}
        for key, val in st_leaf.items():
            if key in paged_keys:
                out[key] = _scatter_blocks(val, pf_leaf[paged_keys[key]],
                                           row, nhit, block_size)
            else:
                out[key] = _merge_row(val, pf_leaf[key], slot)
        new_cache.append(out)
    mb = state["block_tables"].shape[1]
    row_arr = np.zeros((mb,), np.int32)
    row_arr[:len(row)] = row
    return {
        "cache": new_cache,
        "token": state["token"].at[slot, 0].set(next_token),
        "pos": state["pos"].at[slot].set(plen),
        "block_tables": state["block_tables"].at[slot].set(
            jnp.asarray(row_arr)),
    }


def _install_draft_paged(cache, prefill_cache, row: list, nhit: int,
                         block_size: int):
    """Scatter a DRAFT-model prefill into the draft's shadow block pools at
    the same physical ids the target admission mapped.  Spec eligibility
    guarantees every draft cache leaf is paged (no SSM/SWA per-row state),
    so unlike `_install_slot_paged` there is no per-row merge arm."""
    paged_keys = {"kp": "k", "vp": "v", "ckvp": "ckv", "kropep": "krope"}
    new_cache = []
    for st_leaf, pf_leaf in zip(cache, prefill_cache):
        new_cache.append({
            key: _scatter_blocks(val, pf_leaf[paged_keys[key]],
                                 row, nhit, block_size)
            for key, val in st_leaf.items()})
    return new_cache


def _scatter_blocks(pool, src, row: list, nhit: int, block_size: int):
    """Scatter a dense prefill leaf (groups, 1, T', ...) into pool blocks
    (groups, nb, bs, ...) `row[nhit:]` (hit blocks are left untouched)."""
    rows = jnp.moveaxis(src, 1, 0)[0]            # (groups, T', ...)
    Tp = rows.shape[1]
    n_pb = -(-Tp // block_size)
    pad = n_pb * block_size - Tp
    if pad:
        spec = [(0, 0)] * rows.ndim
        spec[1] = (0, pad)
        rows = jnp.pad(rows, spec)
    rows = rows.reshape((rows.shape[0], n_pb, block_size) + rows.shape[2:])
    if nhit >= n_pb:
        return pool
    ids = jnp.asarray(np.asarray(row[nhit:n_pb], np.int32))
    return pool.at[:, ids].set(rows[:, nhit:].astype(pool.dtype))


def _gather_blocks(cache, row: list, paged_keys) -> list:
    """Gather a slot's block chain out of every layer's paged pools into
    contiguous host buffers — the export half of the KV handoff.  The
    gather (``pool[:, ids]``) runs device-side; ONE ``device_get`` over
    the whole pytree then pulls every layer in a single host transfer."""
    ids = jnp.asarray(np.asarray(row, np.int32))
    bufs = [{k: leaf[k][:, ids] for k in leaf if k in paged_keys}
            for leaf in cache]
    host = jax.device_get(bufs)
    return [{k: np.asarray(v) for k, v in leaf.items()} for leaf in host]


def _import_blocks_paged(state, bufs: list, slot: int, plen: int,
                         next_token: int, row: list, nhit: int,
                         block_size: int):
    """Scatter handoff buffers (per layer, ``(groups, n_pb, bs, ...)``)
    into pool blocks ``row[nhit:n_pb]`` (prefix-hit blocks already hold
    bit-identical content) and install the slot's table row, token and
    position — the import half of the KV handoff, mirroring
    `_install_slot_paged` with host buffers in place of a prefill."""
    n_pb = -(-plen // block_size)
    new_cache = []
    ids = (None if nhit >= n_pb
           else jnp.asarray(np.asarray(row[nhit:n_pb], np.int32)))
    for st_leaf, hb in zip(state["cache"], bufs):
        out = dict(st_leaf)
        if ids is not None:
            for key, buf in hb.items():
                out[key] = st_leaf[key].at[:, ids].set(
                    jnp.asarray(buf[:, nhit:]).astype(st_leaf[key].dtype))
        new_cache.append(out)
    mb = state["block_tables"].shape[1]
    row_arr = np.zeros((mb,), np.int32)
    row_arr[:len(row)] = row
    return {
        "cache": new_cache,
        "token": state["token"].at[slot, 0].set(next_token),
        "pos": state["pos"].at[slot].set(plen),
        "block_tables": state["block_tables"].at[slot].set(
            jnp.asarray(row_arr)),
    }


def _fit_rows(src, dst_shape):
    """Pad/crop the row dim of src (groups, T', ...) to dst (groups, T, ...)."""
    if src.shape == tuple(dst_shape):
        return src
    out = src
    for ax in range(len(dst_shape)):
        T, Tp = dst_shape[ax], out.shape[ax]
        if Tp > T:
            out = jax.lax.slice_in_dim(out, 0, T, axis=ax)
        elif Tp < T:
            pad = [(0, 0)] * out.ndim
            pad[ax] = (0, T - Tp)
            out = jnp.pad(out, pad)
    return out
