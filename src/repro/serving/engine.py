"""Batched serving engine: wave-scheduled static-slot batching.

The engine owns a fixed (slots, max_len) KV-cache block compiled ONCE into
a single decode executable; admission never recompiles.  Requests are
scheduled in *waves*: when all slots are free, up to `slots` requests are
pulled from the queue, left-padded to a common prompt bucket, prefilled
slot-by-slot into the shared cache block, and then decoded TOGETHER — one
batched decode step per token until every slot finishes.  A slot whose
request completes early idles until the wave ends (the classic static-
batching trade; per-slot positions — continuous batching — would need a
vectorized `pos` through the decode path and is listed as future work in
DESIGN.md).

In the pilot system this engine is one *payload*: ``serve`` tasks late-bind
it onto an already-held slice, and a pilot can run several engine waves for
different models back-to-back without re-provisioning — the paper's
multi-payload pilot, applied to inference.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import build_model, init_decode_state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted: float = dataclasses.field(default_factory=time.monotonic)
    # filled on completion
    tokens: list = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    done_s: float | None = None


@dataclasses.dataclass
class SlotState:
    rid: int = -1                      # -1 == free
    remaining: int = 0


def admit_length(prompt_len: int, max_len: int) -> int:
    """Round a prompt length up to its power-of-two bucket, rejecting
    prompts that cannot decode a single token inside the (slots, max_len)
    cache block.  Raises ValueError instead of silently cropping.

    The bucket is capped at ``max_len - 1``: prefill occupies ``plen``
    positions and decode starts writing KV at ``pos == plen``, so a bucket
    equal to ``max_len`` would leave zero decode room (the first decode
    write clamps onto the last prompt position and corrupts its cache row).
    """
    if prompt_len >= max_len:
        raise ValueError(
            f"prompt length {prompt_len} does not fit engine max_len "
            f"{max_len} (needs prompt + >=1 generated token); truncate the "
            f"prompt or build the engine with a larger max_len")
    b = 16
    while b < prompt_len:
        b *= 2
    return min(b, max_len - 1)


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.bundle = build_model(cfg)
        self.state = init_decode_state(cfg, slots, max_len)
        self.slot_meta = [SlotState() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}
        self._live: dict[int, Request] = {}
        self.steps = 0
        self.idle_slot_steps = 0       # static-batching waste metric

        # one compiled decode step for the whole engine lifetime
        self._decode = jax.jit(self.bundle.decode, donate_argnums=1)
        # prefill compiles per prompt-length bucket
        self._prefill_cache: dict[int, Callable] = {}

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        """Admit a request.  A prompt that cannot fit the engine's KV block
        (prompt + at least one generated token within ``max_len``) is
        rejected here, explicitly — the old behavior silently clamped the
        bucket to ``max_len`` and then left-pad indexing wrote the prompt
        out of range."""
        admit_length(len(req.prompt), self.max_len)
        self.queue.append(req)

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            self._prefill_cache[plen] = jax.jit(
                lambda p, b: self.bundle.prefill(p, b))
        return self._prefill_cache[plen]

    def _bucket(self, n: int) -> int:
        return admit_length(n, self.max_len)

    # ------------------------------------------------------------------

    def _start_wave(self):
        """Admit up to `slots` queued requests; prefill each into its slot."""
        wave = []
        while self.queue and len(wave) < self.slots:
            wave.append(self.queue.popleft())
        if not wave:
            return
        plen = max(self._bucket(len(r.prompt)) for r in wave)
        self.state = init_decode_state(self.cfg, self.slots, self.max_len)
        for si, req in enumerate(wave):
            toks = np.zeros((1, plen), np.int32)
            toks[0, -len(req.prompt):] = req.prompt          # left-pad
            logits, cache = self._prefill_fn(plen)(
                self.params, {"tokens": jnp.asarray(toks)})
            nxt = int(jnp.argmax(logits[0, -1]))
            self.state = _install_slot(self.state, cache, si, plen, nxt)
            meta = self.slot_meta[si]
            meta.rid, meta.remaining = req.rid, req.max_new_tokens
            req.tokens.append(nxt)
            req.first_token_s = time.monotonic() - req.submitted
            self._live[req.rid] = req
        self.state = {**self.state, "pos": jnp.asarray(plen, jnp.int32)}

    def step(self) -> int:
        """One engine iteration.  Returns number of tokens decoded."""
        live = [m for m in self.slot_meta if m.rid != -1]
        if not live:
            self._start_wave()
            live = [m for m in self.slot_meta if m.rid != -1]
            if not live:
                return 0
        logits, self.state = self._decode(self.params, self.state)
        self.steps += 1
        self.idle_slot_steps += self.slots - len(live)
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for si, meta in enumerate(self.slot_meta):
            if meta.rid == -1:
                continue
            req = self._live[meta.rid]
            req.tokens.append(int(toks[si]))
            meta.remaining -= 1
            if meta.remaining <= 0 or int(self.state["pos"]) >= self.max_len - 1:
                req.done_s = time.monotonic() - req.submitted
                self.done[req.rid] = req
                del self._live[meta.rid]
                meta.rid = -1
        return len(live)

    def run(self, *, max_steps: int = 10_000) -> dict:
        t0 = time.monotonic()
        decoded = 0
        while (self.queue or self._live) and self.steps < max_steps:
            decoded += self.step()
        wall = time.monotonic() - t0
        util = (decoded / (self.steps * self.slots)) if self.steps else 0.0
        return {
            "completed": len(self.done),
            "decode_steps": self.steps,
            "tokens_decoded": decoded,
            "slot_utilization": util,
            "wall_s": wall,
            "tok_per_s": decoded / wall if wall else 0.0,
            "mean_ttft_s": float(np.mean([r.first_token_s
                                          for r in self.done.values()]))
            if self.done else None,
        }


# --------------------------------------------------------------------------


def _install_slot(state, prefill_cache, slot: int, plen: int, next_token: int):
    """Copy one prefilled request's cache rows into batch row `slot` of the
    engine's shared decode state.  All LM cache leaves are stacked
    (n_groups/L, B, ...), so the batch dim is 1 everywhere."""
    def merge(dst, src):
        src_b = jnp.moveaxis(src, 1, 0)[0]           # drop batch (=1)
        dst_b = jnp.moveaxis(dst, 1, 0)              # (B, groups, ...)
        dst_b = dst_b.at[slot].set(
            _fit_rows(src_b, dst_b.shape[1:]).astype(dst.dtype))
        return jnp.moveaxis(dst_b, 0, 1)

    new_cache = jax.tree.map(merge, state["cache"], prefill_cache)
    token = state["token"].at[slot, 0].set(next_token)
    return {"cache": new_cache, "token": token, "pos": state["pos"]}


def _fit_rows(src, dst_shape):
    """Pad/crop the row dim of src (groups, T', ...) to dst (groups, T, ...)."""
    if src.shape == tuple(dst_shape):
        return src
    out = src
    for ax in range(len(dst_shape)):
        T, Tp = dst_shape[ax], out.shape[ax]
        if Tp > T:
            out = jax.lax.slice_in_dim(out, 0, T, axis=ax)
        elif Tp < T:
            pad = [(0, 0)] * out.ndim
            pad[ax] = (0, T - Tp)
            out = jnp.pad(out, pad)
    return out
