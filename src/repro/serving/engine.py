"""Batched serving engine: continuous batching over static slots.

The engine owns a fixed (slots, max_len) KV-cache block compiled ONCE into
a single decode executable; admission never recompiles.  ``pos`` is a
per-slot ``(slots,)`` vector threaded through the whole decode path
(models/api.py -> attention per-row ring writes and ragged KV lengths), so
every slot decodes at its own absolute position.  A slot whose request
finishes is refilled IMMEDIATELY: the next queued request is prefilled into
just that batch row (`_install_slot`) while the other slots keep decoding —
no wave barrier, no decode-state reallocation, no idle slots while work is
queued.

Per-slot ``pos`` invariants:

* after admission into slot ``s`` with prompt bucket ``plen``,
  ``pos[s] == plen`` and cache rows ``0..plen-1`` of row ``s`` hold the
  (left-padded) prompt KV;
* each decode step writes row ``s``'s KV at ``pos[s]`` and advances
  ``pos[s] += 1`` — rows never interact, so admitting a request mid-decode
  leaves every other slot's token stream bitwise identical to a solo run;
* a slot is evicted when ``pos[s]`` reaches ``max_len`` (its cache row is
  full) or its token budget is spent — both checked ON DEVICE;
* free slots keep stepping over garbage in their own row (cheaper than
  masking the batched matmuls); admission overwrites the row wholesale.

One-transfer-per-step rule: the decode loop is device-resident.  A single
jitted step (donated state) decodes, argmaxes, debits the per-slot token
budget and computes the done mask on device, returning one packed
``(2, slots)`` int32 array — tokens and done flags — which is the ONLY
device→host transfer of the step (``d2h_transfers`` counts them; tests
assert ``d2h_transfers == steps``).  The wave-era engine pulled ``pos``
once per live slot plus an argmax round-trip per request.

In the pilot system this engine is a first-class *payload*: ``serve``
tasks late-bind it onto an already-held slice and drive it from a request
trace in the startup spec (core/images.py + core/wrapper.py) — the paper's
multi-payload pilot, applied to inference.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import build_model, init_decode_state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted: float = dataclasses.field(default_factory=time.monotonic)
    # filled on completion
    tokens: list = dataclasses.field(default_factory=list)
    first_token_s: float | None = None
    done_s: float | None = None


@dataclasses.dataclass
class SlotState:
    rid: int = -1                      # -1 == free


def admit_length(prompt_len: int, max_len: int) -> int:
    """Round a prompt length up to its power-of-two bucket, rejecting
    prompts that cannot decode a single token inside the (slots, max_len)
    cache block.  Raises ValueError instead of silently cropping.

    The bucket is capped at ``max_len - 1``: prefill occupies ``plen``
    positions and decode starts writing KV at ``pos == plen``, so a bucket
    equal to ``max_len`` would leave zero decode room (the first decode
    write clamps onto the last prompt position and corrupts its cache row).
    """
    if prompt_len >= max_len:
        raise ValueError(
            f"prompt length {prompt_len} does not fit engine max_len "
            f"{max_len} (needs prompt + >=1 generated token); truncate the "
            f"prompt or build the engine with a larger max_len")
    b = 16
    while b < prompt_len:
        b *= 2
    return min(b, max_len - 1)


def make_engine_step(bundle, max_len: int):
    """The engine's jitted decode step: decode + argmax + per-slot budget
    debit + done mask, all on device, returning one packed (2, slots) int32
    array.  Module-level so engines built over the SAME bundle/max_len (a
    serve image's factory) share one jit wrapper — which is what lets
    ``ExecutableRegistry.prefetch`` stage the XLA compile before the
    payload's first tick."""
    def step(params, state, active, budget):
        logits, new_state = bundle.decode(params, state)       # argmax inside
        tok = new_state["token"][:, 0]
        budget = budget - active.astype(jnp.int32)
        done = active & ((budget <= 0) | (new_state["pos"] >= max_len))
        packed = jnp.stack([tok, done.astype(jnp.int32)])      # (2, slots)
        return packed, new_state, active & ~done, budget

    return jax.jit(step, donate_argnums=(1, 2, 3))


class ServeEngine:
    """Continuous-batching engine.  ``admission="wave"`` restores the old
    wave-scheduled baseline (refill only when every slot has drained) so
    benchmarks can quantify the win on identical workloads.

    ``bundle``/``step_fn``/``prefill_fn`` let a serve image's factory share
    one model bundle and its jitted step/prefill wrappers across engine
    instances (jit caches are per wrapper, so sharing the wrapper is what
    makes a prefetched compile reusable)."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 admission: str = "continuous", bundle=None, step_fn=None,
                 prefill_fn=None):
        assert admission in ("continuous", "wave"), admission
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.admission = admission
        self.bundle = bundle or build_model(cfg)
        self.state = init_decode_state(cfg, slots, max_len)   # pos: (slots,)
        self.budget = jnp.zeros((slots,), jnp.int32)          # device-side
        self.active = jnp.zeros((slots,), bool)               # device-side
        self.slot_meta = [SlotState() for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}
        self._live: dict[int, Request] = {}
        self.steps = 0
        self.idle_slot_steps = 0       # slots with no request during a step
        self.d2h_transfers = 0         # must equal `steps` (one per step)

        # one compiled decode step for the whole engine lifetime; engine
        # state (decode state + budget + active) is donated every step
        self._step_fn = step_fn or make_engine_step(self.bundle, max_len)
        # one jitted prefill wrapper; jax re-traces per prompt bucket shape
        self._prefill = prefill_fn or jax.jit(self.bundle.prefill)

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        """Admit a request.  A prompt that cannot fit the engine's KV block
        (prompt + at least one generated token within ``max_len``) is
        rejected here, explicitly — never silently cropped."""
        admit_length(len(req.prompt), self.max_len)
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        return admit_length(n, self.max_len)

    # ------------------------------------------------------------------
    # slot-granular admission
    # ------------------------------------------------------------------

    def _admit(self):
        """Fill free slots from the queue.  Continuous mode refills any free
        slot immediately; wave mode (baseline) only refills once ALL slots
        have drained."""
        free = [i for i, m in enumerate(self.slot_meta) if m.rid == -1]
        if not free or not self.queue:
            return
        if self.admission == "wave" and len(free) < self.slots:
            return
        for si in free:
            if not self.queue:
                break
            self._admit_into(si, self.queue.popleft())

    def _admit_into(self, si: int, req: Request):
        """Prefill one request into batch row `si` while the other slots'
        decode state stays untouched."""
        plen = self._bucket(len(req.prompt))
        toks = np.zeros((1, plen), np.int32)
        toks[0, -len(req.prompt):] = req.prompt               # left-pad
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)})
        nxt = int(jnp.argmax(logits[0, -1]))                  # admission-time
        self.state = _install_slot(self.state, cache, si, plen, nxt)
        self.active = self.active.at[si].set(True)
        self.budget = self.budget.at[si].set(req.max_new_tokens)
        self.slot_meta[si].rid = req.rid
        req.tokens.append(nxt)
        req.first_token_s = time.monotonic() - req.submitted
        self._live[req.rid] = req

    # ------------------------------------------------------------------

    def step(self) -> int:
        """One engine iteration: admit into free slots, then one batched
        decode step.  Returns the number of live slots decoded (0 when the
        engine is idle — an idle tick is not a decode step)."""
        self._admit()
        n_live = sum(1 for m in self.slot_meta if m.rid != -1)
        if n_live == 0:
            return 0
        packed, self.state, self.active, self.budget = self._step_fn(
            self.params, self.state, self.active, self.budget)
        self.steps += 1
        self.idle_slot_steps += self.slots - n_live
        out = jax.device_get(packed)       # THE device→host transfer
        self.d2h_transfers += 1
        toks, dones = out[0], out[1]
        now = time.monotonic()
        for si, meta in enumerate(self.slot_meta):
            if meta.rid == -1:
                continue
            req = self._live[meta.rid]
            req.tokens.append(int(toks[si]))
            if dones[si]:
                req.done_s = now - req.submitted
                self.done[req.rid] = req
                del self._live[meta.rid]
                meta.rid = -1
        return n_live

    # ------------------------------------------------------------------

    def run(self, *, max_steps: int = 10_000) -> dict:
        t0 = time.monotonic()
        decoded = 0
        while (self.queue or self._live) and self.steps < max_steps:
            decoded += self.step()
        return self._stats(decoded, time.monotonic() - t0)

    def run_trace(self, trace, *, max_ticks: int = 100_000,
                  on_tick=None) -> dict:
        """Drive the engine from a request *trace* with staggered arrivals.

        ``trace`` is a list of JSON-able dicts (the startup-spec format the
        pilot system ships to a serve payload):
        ``{"rid", "prompt": [ints], "max_new_tokens", "at_step"}`` — the
        request becomes visible to admission at tick ``at_step``.  Idle
        ticks (waiting for an arrival) advance time but are not decode
        steps.

        ``on_tick(tick, step_seconds)`` (optional) runs after every tick —
        the wrapper's heartbeat/stop hook; returning False aborts the run.
        """
        pending = sorted(enumerate(trace),
                         key=lambda ie: int(ie[1].get("at_step", 0)))
        t0 = time.monotonic()
        decoded, tick, i = 0, 0, 0
        while i < len(pending) or self.queue or self._live:
            while i < len(pending) and int(pending[i][1].get("at_step", 0)) <= tick:
                idx, e = pending[i]
                i += 1
                self.submit(Request(
                    rid=int(e.get("rid", idx)),
                    prompt=np.asarray(e["prompt"], np.int32),
                    max_new_tokens=int(e.get("max_new_tokens", 16))))
            t_step = time.monotonic()
            decoded += self.step()
            tick += 1
            if on_tick is not None and on_tick(
                    tick, time.monotonic() - t_step) is False:
                break
            if tick >= max_ticks:
                break
        return self._stats(decoded, time.monotonic() - t0)

    def _stats(self, decoded: int, wall: float) -> dict:
        util = (decoded / (self.steps * self.slots)) if self.steps else 0.0
        ttfts = [r.first_token_s for r in self.done.values()
                 if r.first_token_s is not None]
        tpots = [(r.done_s - r.first_token_s) / max(1, len(r.tokens) - 1)
                 for r in self.done.values()
                 if r.done_s is not None and r.first_token_s is not None
                 and len(r.tokens) > 1]
        pct = lambda v, q: float(np.percentile(v, q)) if v else None
        return {
            "completed": len(self.done),
            "decode_steps": self.steps,
            "tokens_decoded": decoded,
            "slot_utilization": util,
            "idle_slot_steps": self.idle_slot_steps,
            "d2h_transfers": self.d2h_transfers,
            "wall_s": wall,
            "tok_per_s": decoded / wall if wall else 0.0,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p99_s": pct(ttfts, 99),
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p99_s": pct(tpots, 99),
        }

    def reset_metrics(self):
        """Zero the counters/results between benchmark phases (e.g. after a
        jit-warmup run) without touching compiled functions or slot state."""
        assert not self._live and not self.queue, "engine still has work"
        self.steps = 0
        self.idle_slot_steps = 0
        self.d2h_transfers = 0
        self.done.clear()


# --------------------------------------------------------------------------


def _install_slot(state, prefill_cache, slot: int, plen: int, next_token: int):
    """Copy one prefilled request's cache rows into batch row `slot` of the
    engine's shared decode state and reset that row's position to `plen`.
    All LM cache leaves are stacked (n_groups/L, B, ...), so the batch dim
    is 1 everywhere."""
    def merge(dst, src):
        src_b = jnp.moveaxis(src, 1, 0)[0]           # drop batch (=1)
        dst_b = jnp.moveaxis(dst, 1, 0)              # (B, groups, ...)
        dst_b = dst_b.at[slot].set(
            _fit_rows(src_b, dst_b.shape[1:]).astype(dst.dtype))
        return jnp.moveaxis(dst_b, 0, 1)

    new_cache = jax.tree.map(merge, state["cache"], prefill_cache)
    token = state["token"].at[slot, 0].set(next_token)
    pos = state["pos"].at[slot].set(plen)
    return {"cache": new_cache, "token": token, "pos": pos}


def _fit_rows(src, dst_shape):
    """Pad/crop the row dim of src (groups, T', ...) to dst (groups, T, ...)."""
    if src.shape == tuple(dst_shape):
        return src
    out = src
    for ax in range(len(dst_shape)):
        T, Tp = dst_shape[ax], out.shape[ax]
        if Tp > T:
            out = jax.lax.slice_in_dim(out, 0, T, axis=ax)
        elif Tp < T:
            pad = [(0, 0)] * out.ndim
            pad[ax] = (0, T - Tp)
            out = jnp.pad(out, pad)
    return out
