"""FleetDispatcher — a fleet-wide serve request pool (requeue-on-pilot-failure).

The single-engine serve path binds one request *trace* to one engine: if
that engine's pilot dies, its in-flight requests die with it.  The fleet
dispatcher is the late-binding analog of task requeue applied to SERVING
(paper §3.4/§3.6: the slice claim outlives the payload, but resource
*ownership* churns):

* a request trace is split into per-request entries in a dedicated
  :class:`~repro.core.taskrepo.TaskRepo` — same leases, same matchmaking
  index, same deadline-heap reaper that already makes dead pilots harmless
  for batch tasks;
* serving pilots LEASE requests (:meth:`fetch`) into free engine slots and
  piggyback per-request progress on lease renewal (:meth:`renew`) every
  engine tick;
* a pilot that dies simply stops renewing: the repo's lease-expiry reaper
  requeues its in-flight requests and wakes any surviving server parked in
  ``fetch`` — the survivor replays them from the prompt (greedy decode over
  slot-isolated state is deterministic, so the replayed tokens are bitwise
  the tokens the dead pilot would have produced);
* completion is EXACTLY ONCE per request id: :meth:`complete` routes
  through ``TaskRepo.complete`` (first completion wins), so a slow original
  server racing a replayed copy produces one accepted result and one
  counted duplicate — never two.

Request lease lifecycle::

    submit ──> queued ──> leased(server A) ──renew──> ... ──> completed
                  ^            │ no renew (A died)                 ^
                  └── requeued ┘ after lease_ttl (+ backoff)       │
                  └────────────── leased(server B), replay ────────┘

Gray-failure hardening (:class:`RobustnessPolicy`) — a clean crash is the
EASY failure; these paths handle the ones the lease reaper cannot see:

* **progress watchdog** — renewals carry per-request progress, so a
  request renewing on schedule but FROZEN past ``stall_deadline`` is
  revoked (requeued elsewhere) and its server benched (``sick_cooldown``);
* **hedged re-dispatch** — a leased request whose in-flight age exceeds a
  pool-percentile service budget gets a duplicate dispatch with an
  anti-affinity predicate; first completion wins (the existing exactly-
  once rule), the loser is tombstoned and its server cancels the slot;
* **poison quarantine** — per-request blast-radius accounting: a request
  implicated (held with zero progress) in ``quarantine_after`` distinct
  pilot deaths settles FAILED with a recorded reason instead of serially
  killing its way through ``max_attempts`` pilots.  Once-implicated
  requests are *canaried*: dispatched at most one per server, so the next
  death identifies the poison unambiguously instead of condemning its
  whole co-fetched cohort;
* **requeue backoff** — failure requeues stamp ``not_before``
  (exponential + deterministic jitter, ``BackoffPolicy``) so a crashing
  request cannot hot-loop through the fleet at lease-TTL cadence.

Pools register under a process-global name (the simulation's stand-in for
a network endpoint): a serve payload finds its pool with
:func:`get_pool(spec["dispatch"])` from inside the payload container.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import uuid
from collections import deque

from repro.analysis.locks import (
    RANK_POOL,
    audit_callback,
    make_condition,
    make_lock,
)
from repro.core.taskrepo import BackoffPolicy, TaskRepo, TaskResult
from repro.core.timerwheel import shared_wheel

_POOLS: dict[str, "FleetDispatcher"] = {}
_POOLS_LOCK = make_lock("dispatch.pools-registry")


def _canary_ok(ad) -> bool:
    """Canary placement predicate: a SUSPECT (death-implicated) request only
    matches a server whose current requests have ALL produced tokens —
    progress proves they are not the poison (the poison never progresses),
    so if the canary dies the suspect is implicated unambiguously.  Routed
    through the repo's requirements matchmaking so an eligible server picks
    the suspect up the moment it parks in fetch — no defer/retry ping-pong
    inflating the suspect's TTFT."""
    return bool(ad.get("canary_ok"))


def get_pool(name: str) -> "FleetDispatcher | None":
    """Resolve a pool name published in a serve payload's startup spec."""
    with _POOLS_LOCK:
        return _POOLS.get(name)


@dataclasses.dataclass
class RobustnessPolicy:
    """Gray-failure hardening knobs (the ``AutoscalePolicy`` idiom: one
    dataclass, sane defaults, no inline constants).  The zero/None values
    disable the corresponding mechanism; :meth:`conservative` is the
    do-no-harm default a bare ``FleetDispatcher()`` gets — backoff only,
    detection layers off — so non-chaos callers keep PR-4 semantics."""
    # progress watchdog: revoke a renewing-but-frozen request after this
    # many seconds without progress, and bench its server
    stall_deadline: float = 2.0          # 0 disables
    sick_cooldown: float = 2.0           # seconds a stalled server is benched
    # hedged re-dispatch: duplicate a leased request once its in-flight age
    # exceeds max(hedge_min_s, hedge_factor * pNN(recent service times))
    hedging: bool = True
    hedge_percentile: float = 95.0
    hedge_factor: float = 3.0
    hedge_min_s: float = 2.0             # budget floor / cold-start budget
    hedge_min_samples: int = 8           # completions before pNN is trusted
    max_hedges: int = 1                  # duplicate dispatches per request
    watchdog_interval: float = 0.1       # hedge-scan period (s)
    # bench a server once this many of its held requests needed hedging
    # (a SLOW server keeps making progress — the stall watchdog never
    # fires — but trapping request after request past the straggler
    # budget is the same sickness); 0 disables
    bench_after_hedges: int = 0
    # poison quarantine: distinct pilot deaths (implicated with zero
    # progress) before the request settles failed; 0 disables
    quarantine_after: int = 2
    # failure-requeue backoff (threaded into the request repo)
    backoff: BackoffPolicy = dataclasses.field(
        default_factory=lambda: BackoffPolicy(base=0.05, cap=2.0))

    @classmethod
    def conservative(cls) -> "RobustnessPolicy":
        """Backoff-only: no stall revocation, no hedging, no quarantine.
        The default for pools that did not opt into chaos hardening."""
        return cls(stall_deadline=0.0, hedging=False, quarantine_after=0)


@dataclasses.dataclass
class RequestRecord:
    """Dispatcher-side state of one request across its (re)dispatches."""
    rid: int
    task_id: int
    entry: dict                         # the JSON-able request body
    submitted_s: float                  # monotonic submit time (TTFT zero)
    tokens: list | None = None          # accepted completion (first wins)
    server: str | None = None           # the server whose result won
    first_token_s: float | None = None  # pool-level TTFT (includes requeue)
    completed_s: float | None = None
    attempts: int = 0                   # dispatches (>1 == replayed)
    progress: int = 0                   # tokens reported via renew()
    failed: bool = False                # rejected max_attempts times
    servers_tried: list = dataclasses.field(default_factory=list)
    # blast radius: distinct pilots that died while holding this request
    # with zero recorded progress (the quarantine signal)
    implicated: set = dataclasses.field(default_factory=set)
    quarantined: bool = False
    fail_reason: str | None = None
    hedges: int = 0                     # duplicate dispatches issued
    hedge_tids: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _HeldLease:
    """Per-(server, rid) lease-side state: the repo task plus the progress
    trail the stall watchdog and blast-radius blame read."""
    task: object                        # the leased PayloadTask
    t: float                            # fetch time (hedge age zero)
    progress: int = -1                  # last tokens reported by THIS server
    t_progress: float = 0.0             # when progress last advanced
    t_renew: float = 0.0                # last successful lease renewal


class FleetDispatcher:
    def __init__(self, *, name: str | None = None, lease_ttl: float = 1.0,
                 max_attempts: int = 8,
                 policy: RobustnessPolicy | None = None):
        self.name = name or f"pool-{uuid.uuid4().hex[:8]}"
        self.policy = policy or RobustnessPolicy.conservative()
        # a DEDICATED repo: request leases expire on their own (short) TTL,
        # independent of the pilot-level task leases.  The repo calls back
        # on every lease expiry (a presumed pilot death) for blast-radius
        # accounting, and applies the policy's backoff to failure requeues.
        self.repo = TaskRepo(lease_ttl=lease_ttl,
                             backoff=self.policy.backoff,
                             on_expired=self._on_lease_expired)
        self.max_attempts = max_attempts
        # RANK_POOL < RANK_REPO: fetch/complete/release may call into the
        # repo while holding the pool lock, never the reverse.  Instance-
        # named so the disagg prefill->decode chain (two pool locks in a
        # fixed order) reads as two graph nodes, not a self-edge.
        self._lock = make_lock(f"dispatch.pool[{self.name}]", rank=RANK_POOL)
        self._done_cond = make_condition(self._lock)
        self._records: dict[int, RequestRecord] = {}
        self._by_tid: dict[int, int] = {}
        # (server_id, rid) -> _HeldLease (task + progress trail)
        self._leased: dict[tuple[str, int], _HeldLease] = {}
        self._n_settled = 0               # completed + failed
        self.duplicates = 0               # completions dropped by first-wins
        self.lost_leases = 0              # renewals refused (re-leased away)
        self.hedges = 0                   # hedged duplicate dispatches
        self.stalls_revoked = 0           # watchdog revocations
        self.quarantined = 0              # requests settled by blast radius
        self.servers: set[str] = set()    # servers that announced readiness
        # server_id -> bench-until stamp: stalled/implicated servers are
        # refused fetches and excluded from capacity sizing until this
        self._sick: dict[str, float] = {}
        # server_id -> held requests that crossed the straggler budget
        # (hedge strikes); at bench_after_hedges the server is benched
        self._hedge_strikes: dict[str, int] = {}
        # pilot_id -> (death stamp, had_suspect): groups the per-lease
        # expiry callbacks of one pilot death into one blame event even
        # when the reaper splits them across batches
        self._deaths: dict[str, tuple[float, bool]] = {}
        # server_id -> (monotonic stamp, engine telemetry sample): the
        # per-tick KV-pressure heartbeat the autoscaler reads; entries
        # go stale after telemetry_ttl (a dead server stops reporting)
        self._telemetry: dict[str, tuple[float, dict]] = {}
        self.telemetry_ttl = max(5.0 * lease_ttl, 2.0)
        # server_id -> announce-time labels ({"pool": "prefill"}, ...):
        # pool_pressure groups telemetry by the "pool" label so a mixed
        # fleet's prefill TTFT never blends into decode TPOT
        self._server_labels: dict[str, dict] = {}
        # completion hook (rec, handoff) -> None, called OUTSIDE the pool
        # lock on every accepted completion — the DisaggRouter's forward
        # edge from the prefill pool into the decode pool
        self.on_complete = None
        # bounded recent-TTFT window so pool_pressure (called every
        # autoscaler tick) never sorts the pool's full request history
        self._recent_ttfts: deque[float] = deque(maxlen=2048)
        self._recent_ttfts_by_label: dict[str, deque] = {}
        # fetch->completion service times: the hedge budget's percentile base
        self._recent_service: deque[float] = deque(maxlen=512)
        self.sealed = threading.Event()   # no further submissions coming
        self.closed = threading.Event()
        self._watchdog_timer = None
        if self.policy.hedging and self.policy.watchdog_interval > 0:
            self._watchdog_timer = shared_wheel().call_periodic(
                self.policy.watchdog_interval, self._watchdog_tick,
                name=f"pool-{self.name}-hedge-watchdog")
        with _POOLS_LOCK:
            _POOLS[self.name] = self

    # ---- submission -------------------------------------------------------

    def submit(self, entry: dict) -> int:
        """Queue one request.  ``entry`` is the trace-entry format
        (``{"rid", "prompt": [ints], "max_new_tokens", ...}``); an optional
        ``require_labels`` dict rides into the repo's matchmaking index so a
        request can be pinned to servers advertising matching labels (e.g.
        one pool feeding several model fleets)."""
        rid = int(entry["rid"])
        if self.sealed.is_set():
            raise RuntimeError(f"pool {self.name} is sealed")
        # record BEFORE publishing: the repo submit wakes parked fetchers,
        # which must always find the record.  The tid->rid mapping may lag
        # by microseconds; fetch falls back to the rid the task itself
        # carries in its payload_spec.
        # a two-stage (disagg) submit carries the ORIGINAL submit stamp so
        # the decode pool's TTFT window measures end-to-end, not since the
        # router's forward
        rec = RequestRecord(rid=rid, task_id=-1, entry=dict(entry),
                            submitted_s=float(entry.get(
                                "submitted_s", time.monotonic())))
        with self._lock:
            if rid in self._records:
                raise ValueError(f"duplicate request id {rid}")
            self._records[rid] = rec
        tid = self.repo.submit(
            "serve-request",
            require_labels=entry.get("require_labels"),
            priority=int(entry.get("priority", 0)),
            max_attempts=self.max_attempts,
            payload_spec={"rid": rid})
        with self._lock:
            rec.task_id = tid
            self._by_tid[tid] = rid
        return rid

    def submit_trace(self, trace: list[dict]) -> list[int]:
        """Split a request trace into per-request pool entries.  Arrival
        staggering (``at_step``) is an engine-tick concept and is ignored
        here — fleet arrivals are wall-clock submissions."""
        return [self.submit(e) for e in trace]

    # ---- the server side (called from serve payloads) ---------------------

    def announce(self, server_id: str, labels: dict | None = None):
        """A server reports it is up and WARM (engine compiled, ready to
        lease).  ``labels`` (e.g. ``{"pool": "prefill"}``) groups this
        server's telemetry in :meth:`pool_pressure`'s ``by_label`` split.
        Drivers that want cold-start excluded from TTFT wait for the
        fleet with :meth:`wait_servers` before submitting traffic."""
        with self._done_cond:
            self.servers.add(server_id)
            if labels:
                self._server_labels[server_id] = dict(labels)
            self._done_cond.notify_all()

    def _label_of(self, server_id: str) -> str:
        return str(self._server_labels.get(server_id, {}).get(
            "pool", "default"))

    def wait_servers(self, n: int, timeout: float | None = None) -> bool:
        return self._wait_for(lambda: len(self.servers) >= n, timeout)

    def retire(self, server_id: str):
        """A server's graceful exit (scale-down drain, tick budget, pool
        finished): drop it from the announced set and forget its telemetry,
        so pool pressure never counts capacity that is gone."""
        with self._done_cond:
            self.servers.discard(server_id)
            self._telemetry.pop(server_id, None)
            self._sick.pop(server_id, None)
            self._hedge_strikes.pop(server_id, None)
            self._done_cond.notify_all()

    def report_telemetry(self, server_id: str, sample: dict):
        """Per-tick engine telemetry heartbeat (kv_memory_utilization,
        blocked_admissions, free_slots, ...) — the demand-side signal the
        autoscaler folds into its scale decisions."""
        with self._lock:
            self._telemetry[server_id] = (time.monotonic(), dict(sample))

    def fetch(self, server_id: str, *, max_n: int = 1, timeout: float = 0.0,
              labels: dict | None = None, cancel=None) -> list[dict]:
        """Lease up to ``max_n`` requests for this server.  The first match
        may block up to ``timeout`` (parked on the repo condition — a
        requeued request wakes it immediately); the rest are non-blocking.
        Returned entries carry ``rid``, ``submitted_s`` (the pool-level TTFT
        zero) and ``attempt``.

        A BENCHED server (stall watchdog) gets nothing until its cooldown
        passes — a stalled payload freeing slots by revocation must not
        immediately refill them with requests it will also black-hole."""
        now = time.monotonic()
        with self._lock:
            sick_until = self._sick.get(server_id, 0.0)
        if now < sick_until:
            if timeout > 0:
                time.sleep(min(timeout, sick_until - now))
            return []
        ad = {"pilot_id": server_id, "labels": dict(labels or {})}
        stop = (self.closed.is_set if cancel is None
                else lambda: self.closed.is_set() or cancel())
        out: list[dict] = []
        for i in range(max_n):
            with self._lock:
                # solo-canary rule: a server holding a SUSPECT (death-
                # implicated) request serves it alone — fetching anything
                # else alongside would let an undetected poison detonate
                # on the canary and condemn the innocent suspect with it
                canarying = any(
                    r in self._records and self._records[r].implicated
                    for (s, r) in self._leased if s == server_id)
                # advertised to the _canary_ok placement predicate;
                # recomputed every iteration — the previous match added a
                # zero-progress lease to this server
                ad["canary_ok"] = all(
                    h.progress > 0 for (s, r), h in self._leased.items()
                    if s == server_id)
            if canarying:
                break
            if i == 0 and timeout > 0:
                task = self.repo.match_wait(ad, timeout=timeout, cancel=stop)
            else:
                task = self.repo.match(ad)
            if task is None:
                break
            with self._lock:
                # the submitter records the task before publishing but may
                # not have written the tid mapping yet — the task's own
                # payload_spec always carries the rid
                rid = self._by_tid.get(task.task_id)
                if rid is None:
                    rid = int(task.payload_spec["rid"])
                    self._by_tid[task.task_id] = rid
                rec = self._records[rid]
                if rec.task_id == -1:
                    rec.task_id = task.task_id
                if rec.tokens is not None or rec.failed:
                    # stale queued copy of an already-settled request (its
                    # lease expired in the same window the original server
                    # finished, or it settled as failed).  failed=rec.failed
                    # routes the failed case into the repo's _failed state
                    # instead of re-enqueueing a zombie that would win every
                    # future match (lowest task_id) and starve the queue.
                    self.repo.release(task, failed=rec.failed,
                                      pilot_id=server_id)
                    continue
                if (server_id, rid) in self._leased:
                    # this server already holds another dispatch of the
                    # same rid (its hedge, or a requeued primary looping
                    # back) — one engine slot per rid per server.  Defer
                    # the copy briefly so another server picks it up.
                    self.repo.release(task, pilot_id=server_id,
                                      defer_s=2 * self.policy.backoff.base
                                      or 0.05)
                    continue
                if (rec.implicated and self.policy.quarantine_after > 0
                        and any(h.progress <= 0
                                for (s, r), h in self._leased.items()
                                if s == server_id)):
                    # canary entry guard (the race the _canary_ok predicate
                    # cannot see: implication landed after the task was
                    # enqueued without requirements): a suspect must not
                    # share a server with a zero-progress request — an
                    # undetected poison among them would detonate on the
                    # canary and condemn the innocent suspect with it
                    self.repo.release(task, pilot_id=server_id,
                                      defer_s=2 * self.policy.backoff.base
                                      or 0.05)
                    continue
                # the previous holder of THIS task is dead or lost the
                # lease — its stale record must not keep counting it as a
                # holder.  Same-tid only: a hedge sibling holds the same
                # rid under a DIFFERENT task id and is a live racer, not a
                # stale holder
                for k in [k for k in self._leased
                          if k[1] == rid and k[0] != server_id
                          and self._leased[k].task.task_id == task.task_id]:
                    del self._leased[k]
                t_now = time.monotonic()
                self._leased[(server_id, rid)] = _HeldLease(
                    task=task, t=t_now, progress=-1, t_progress=t_now,
                    t_renew=t_now)
                rec.attempts = max(rec.attempts, task.attempts)
                rec.servers_tried.append(server_id)
                e = dict(rec.entry)
                e["rid"] = rid
                e["submitted_s"] = rec.submitted_s
                e["attempt"] = task.attempts
            out.append(e)
        return out

    def renew(self, server_id: str, progress: dict[int, int]) -> list[int]:
        """Renew this server's request leases, piggybacking per-request
        progress (tokens produced so far) on the heartbeat.  Returns the
        rids whose lease this server NO LONGER holds (expired and re-leased,
        requeued, or REVOKED by the stall watchdog) — the caller should
        ``ServeEngine.cancel`` them instead of burning slots on tokens that
        can never win.

        The stall watchdog lives here because stalls are exactly the
        failure renewals cannot expose: a stuck payload keeps renewing on
        schedule, so only the piggybacked progress can show it is dead
        weight.  Frozen past ``stall_deadline`` -> the request is revoked
        (requeued elsewhere) and the server benched for ``sick_cooldown``."""
        lost: list[int] = []
        pol = self.policy
        for rid, n_tokens in progress.items():
            now = time.monotonic()
            revoked = None
            with self._lock:
                held = self._leased.get((server_id, rid))
                rec = self._records.get(rid)
                if held is not None and rec is not None:
                    if int(n_tokens) > held.progress:
                        held.progress = int(n_tokens)
                        held.t_progress = now
                        rec.progress = max(rec.progress, int(n_tokens))
                        if int(n_tokens) > 0 and rec.implicated:
                            # exoneration: a suspect that produces TOKENS is
                            # not the poison (poison never progresses) —
                            # drop its strikes and its idle-only canary
                            # routing so it stops paying the suspect tax
                            rec.implicated.clear()
                            held.task.requirements = None
                    elif (pol.stall_deadline > 0
                          and now - held.t_progress > pol.stall_deadline
                          and rec.tokens is None and not rec.failed):
                        del self._leased[(server_id, rid)]
                        self.stalls_revoked += 1
                        self._sick[server_id] = now + pol.sick_cooldown
                        revoked = held.task
            if held is None or rec is None:
                # the lease record was already swept (the rid re-leased to
                # another server, or the pool never knew it) — still a loss
                # from this server's point of view
                if rec is not None and rec.tokens is None:
                    self.lost_leases += 1
                lost.append(rid)
                continue
            if revoked is not None:
                # immediate requeue (no backoff: the REQUEST is healthy,
                # its server is not) — survivors pick it up right away
                self.repo.release(revoked, pilot_id=server_id)
                lost.append(rid)
                continue
            if self.repo.renew(held.task.task_id, server_id):
                held.t_renew = now
            else:
                lost.append(rid)
                self.lost_leases += 1
                with self._lock:
                    self._leased.pop((server_id, rid), None)
        return lost

    def complete(self, server_id: str, rid: int, tokens: list,
                 *, first_token_s: float | None = None,
                 handoff=None) -> bool:
        """Report a finished request.  First completion wins — routed
        through ``TaskRepo.complete``'s result dedup, so a replayed or
        HEDGED copy racing the original produces exactly one accepted
        result.  On a win, every other outstanding dispatch of the rid is
        tombstoned in the repo: leased losers fail their next renew (the
        server cancels the slot), queued copies are lazily purged by the
        match index.

        ``handoff`` (a :class:`~repro.serving.blockpool.KVHandoff`) rides
        a PREFILL-role completion; it is passed to ``on_complete`` — the
        DisaggRouter's forward edge — only for the accepted winner, so
        the decode stage is submitted exactly once per rid no matter how
        many prefill replays raced."""
        with self._lock:
            rec = self._records.get(rid)
            held = self._leased.get((server_id, rid))
        if rec is None:
            return False
        # complete the task THIS server actually holds: under hedging the
        # rid maps to several tids and rec.task_id is only the primary
        tid = held.task.task_id if held is not None else rec.task_id
        accepted = self.repo.complete(TaskResult(
            task_id=tid, pilot_id=server_id, exitcode=0,
            telemetry={"rid": rid, "n_tokens": len(tokens)}))
        loser_tids: list[int] = []
        fire_hook = False
        with self._done_cond:
            self._leased.pop((server_id, rid), None)
            # a request settles EXACTLY once: a late result for a request
            # that already settled as failed (reject path) must not bump
            # _n_settled a second time — that would let wait_all/finished
            # fire with other work still in flight
            if accepted and not rec.failed and rec.tokens is None:
                rec.tokens = list(tokens)
                rec.server = server_id
                rec.first_token_s = first_token_s
                if first_token_s is not None:
                    self._recent_ttfts.append(first_token_s)
                    lab = self._label_of(server_id)
                    self._recent_ttfts_by_label.setdefault(
                        lab, deque(maxlen=2048)).append(first_token_s)
                now = time.monotonic()
                rec.completed_s = now - rec.submitted_s
                if held is not None:
                    self._recent_service.append(now - held.t)
                for k in [k for k in self._leased if k[1] == rid]:
                    lt = self._leased.pop(k).task.task_id
                    if lt != tid:
                        loser_tids.append(lt)
                for lt in {rec.task_id, *rec.hedge_tids} - {tid, -1}:
                    if lt not in loser_tids:
                        loser_tids.append(lt)
                fire_hook = self.on_complete is not None
                if not fire_hook:
                    self._n_settled += 1
                    self._done_cond.notify_all()
            else:
                self.duplicates += 1
                accepted = False
        if fire_hook:
            # the forward hook runs OUTSIDE the pool lock: it submits into
            # ANOTHER pool (its lock + repo lock), and holding this pool's
            # lock across that call is both a lock-order hazard and a
            # deadlock if the downstream ever calls back.  The settled
            # bump is deferred until the forward lands (even on a raising
            # hook), so a driver blocked in wait_all never observes the
            # pool drained while a forward is still in flight — rec.tokens
            # is already set, so racing duplicates/reject/expiry all see
            # the request as settled and cannot double-bump.
            audit_callback("dispatch.on_complete")
            try:
                self.on_complete(rec, handoff)
            finally:
                with self._done_cond:
                    self._n_settled += 1
                    self._done_cond.notify_all()
        for lt in loser_tids:
            self.repo.complete(TaskResult(
                task_id=lt, pilot_id=server_id, exitcode=0,
                telemetry={"rid": rid, "superseded_by": tid}))
        return accepted

    def release(self, server_id: str, rids: list[int]):
        """Hand leased-but-unfinished requests straight back (graceful
        payload end / drain): they requeue immediately instead of waiting
        out the lease TTL."""
        for rid in rids:
            with self._lock:
                held = self._leased.pop((server_id, rid), None)
            if held is not None:
                # pilot_id guard: if the lease already expired and moved,
                # the new holder's lease survives and nothing is duplicated
                self.repo.release(held.task, pilot_id=server_id)

    def reject(self, server_id: str, rid: int):
        """This server can never run the request (e.g. the prompt exceeds
        its engine's max_len).  The request retries elsewhere until the
        pool's ``max_attempts``, then settles as failed — it must not
        ping-pong forever between release and fetch."""
        with self._lock:
            held = self._leased.pop((server_id, rid), None)
            rec = self._records.get(rid)
        if held is None or rec is None:
            return
        self.repo.release(held.task, failed=True, pilot_id=server_id)
        if held.task.attempts >= self.max_attempts:
            with self._done_cond:
                if not rec.failed and rec.tokens is None:
                    rec.failed = True
                    rec.fail_reason = "rejected by every server"
                    self._n_settled += 1
                    self._done_cond.notify_all()

    # ---- gray-failure hardening -------------------------------------------

    def _on_lease_expired(self, task, pilot_id: str) -> str:
        """Death-event hook, called by the repo's lease reaper (outside the
        repo lock) once per expired lease.  Does the blast-radius blame
        accounting and decides the task's disposition: ``"requeue"``
        (normal recovery, with backoff) or ``"drop"`` (settle failed —
        quarantine, or the record is already settled).

        Blame rule: a pilot death strikes the requests it held with ZERO
        recorded progress — a request that renewed with tokens was being
        served fine and is collateral, not cause.  If any already-SUSPECT
        request was among the held set (canary isolation guarantees at
        most one per server), only suspects are struck: the canary
        confirmed its guilt and exonerates the rest of the batch."""
        spec = getattr(task, "payload_spec", None) or {}
        rid = spec.get("rid")
        if rid is None:
            return "requeue"
        rid = int(rid)
        pol = self.policy
        now = time.monotonic()
        quarantine_losers: list[int] = []
        with self._done_cond:
            rec = self._records.get(rid)
            held = self._leased.pop((pilot_id, rid), None)
            if rec is None:
                return "requeue"
            if rec.tokens is not None or rec.failed:
                return "drop"              # already settled: nothing to redo
            if pol.quarantine_after > 0:
                ev = self._deaths.get(pilot_id)
                if ev is None or now - ev[0] > 2.0 * self.repo.lease_ttl:
                    had_suspect = bool(rec.implicated) or any(
                        r in self._records and self._records[r].implicated
                        for (s, r) in self._leased if s == pilot_id)
                    ev = (now, had_suspect)
                    self._deaths[pilot_id] = ev
                had_suspect = ev[1]
                zero_progress = held is None or held.progress <= 0
                # zero progress is NECESSARY for a strike (a request that
                # renewed with tokens was being served fine — collateral,
                # not cause); when a suspect was among the held set, it is
                # also SUFFICIENT only for the suspect (canary confirmed)
                strike = zero_progress and (bool(rec.implicated)
                                            if had_suspect else True)
                if strike:
                    rec.implicated.add(pilot_id)
                    # now a suspect: its requeued task only matches a server
                    # with all-progressed requests (canary placement,
                    # cleared on exoneration)
                    task.requirements = _canary_ok
                    if len(rec.implicated) >= pol.quarantine_after:
                        rec.failed = True
                        rec.quarantined = True
                        rec.fail_reason = (
                            f"quarantined: {len(rec.implicated)} pilots "
                            f"({sorted(rec.implicated)}) died holding it")
                        self.quarantined += 1
                        self._n_settled += 1
                        # revoke every other outstanding dispatch (a hedge
                        # still decoding elsewhere must stop winning slots
                        # for a condemned request)
                        for k in [k for k in self._leased if k[1] == rid]:
                            quarantine_losers.append(
                                self._leased.pop(k).task.task_id)
                        for lt in ({rec.task_id, *rec.hedge_tids}
                                   - {task.task_id, -1}):
                            if lt not in quarantine_losers:
                                quarantine_losers.append(lt)
                        self._done_cond.notify_all()
        if quarantine_losers:
            for lt in quarantine_losers:
                self.repo.complete(TaskResult(
                    task_id=lt, pilot_id=pilot_id, exitcode=0,
                    telemetry={"rid": rid, "quarantined": True}))
            return "drop"
        if rec.quarantined:
            return "drop"
        return "requeue"

    def _watchdog_tick(self):
        """Hedge scan (timer-wheel periodic): find leased, unsettled,
        un-hedged requests whose in-flight age exceeds the pool's service
        budget and dispatch a duplicate with an anti-affinity predicate.
        The budget is a percentile of recent fetch->completion service
        times (times ``hedge_factor``), floored at ``hedge_min_s`` until
        enough samples exist — a cold pool must not hedge its first wave."""
        pol = self.policy
        if not pol.hedging or self.closed.is_set():
            return
        now = time.monotonic()
        to_hedge: list[tuple[int, RequestRecord, list[str]]] = []
        with self._lock:
            if len(self._recent_service) >= pol.hedge_min_samples:
                s = sorted(self._recent_service)
                p = s[min(len(s) - 1,
                          int(pol.hedge_percentile / 100.0 * len(s)))]
                budget = max(pol.hedge_min_s, pol.hedge_factor * p)
            else:
                budget = pol.hedge_min_s
            fresh = 0.5 * self.repo.lease_ttl   # holder-liveness horizon
            by_rid: dict[int, tuple[float, list[str], bool]] = {}
            for (server, rid), held in self._leased.items():
                t0, holders, alive = by_rid.get(rid, (held.t, [], False))
                alive = alive or (now - max(held.t_renew, held.t) <= fresh)
                by_rid[rid] = (min(t0, held.t), holders + [server], alive)
            for rid, (t0, holders, alive) in by_rid.items():
                rec = self._records.get(rid)
                if (rec is None or rec.tokens is not None or rec.failed
                        or rec.implicated     # suspects are canaried solo
                        or rec.hedges >= pol.max_hedges
                        or now - t0 <= budget
                        # hedging is for LIVE stragglers: a holder that
                        # stopped renewing is dead/partitioned — leave it
                        # to the lease reaper so blame accounting lands
                        # instead of racing a duplicate into a fresh pilot
                        or not alive):
                    continue
                rec.hedges += 1
                self.hedges += 1
                to_hedge.append((rid, rec, sorted(set(holders))))
                if pol.bench_after_hedges > 0:
                    for server in set(holders):
                        n = self._hedge_strikes.get(server, 0) + 1
                        self._hedge_strikes[server] = n
                        if n >= pol.bench_after_hedges:
                            # a server that keeps trapping requests past
                            # the straggler budget is SLOW-sick: bench it
                            # (no new fetches, excluded from capacity)
                            # even though its progress renewals look fine
                            self._sick[server] = now + pol.sick_cooldown
                            self._hedge_strikes[server] = 0
        for rid, rec, holders in to_hedge:
            excl = frozenset(holders)
            tid = self.repo.submit(
                "serve-request",
                # anti-affinity: the duplicate must land on a DIFFERENT
                # server — racing the straggler against itself is pointless
                requirements=lambda ad, _x=excl: ad["pilot_id"] not in _x,
                priority=int(rec.entry.get("priority", 0)),
                max_attempts=self.max_attempts,
                payload_spec={"rid": rid, "hedge": True})
            with self._lock:
                rec.hedge_tids.append(tid)
                self._by_tid[tid] = rid

    # ---- driver side ------------------------------------------------------

    def seal(self):
        """Declare that no further requests will be submitted.  Servers
        keep serving a momentarily-drained pool (elastic traffic!) until it
        is sealed AND everything has settled — only then does
        :meth:`finished` let them exit."""
        self.sealed.set()
        with self._done_cond:
            self._done_cond.notify_all()

    def finished(self) -> bool:
        """True once the pool is sealed and every submitted request has
        settled (completed or failed).  An unsealed pool is never finished
        — more traffic may arrive, servers park in fetch."""
        if not self.sealed.is_set():
            return False
        self._absorb_repo_failures()
        with self._lock:
            return self._n_settled == len(self._records)

    def wait_all(self, timeout: float | None = None) -> bool:
        """Block until every submitted request settles."""
        return self._wait_for(
            lambda: bool(self._records)
            and self._n_settled == len(self._records), timeout)

    def wait_completed(self, n: int, timeout: float | None = None) -> bool:
        """Block until at least ``n`` requests have settled — the hook a
        failure-injection driver uses to kill a pilot MID-trace."""
        return self._wait_for(lambda: self._n_settled >= n, timeout)

    def _wait_for(self, pred, timeout: float | None) -> bool:
        """Condition-wait for ``pred`` (evaluated under the pool lock).
        The wait is bounded to short slices so repo-level settlements that
        bypass the pool's notifications (the reaper failing a request whose
        attempt budget died with a lease) are absorbed promptly."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            self._absorb_repo_failures()
            with self._done_cond:
                if pred():
                    return True
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return False
                self._done_cond.wait(
                    timeout=0.25 if remaining is None
                    else min(0.25, remaining))

    def _absorb_repo_failures(self):
        """Settle records whose repo task failed without any server
        reporting it (attempt budget exhausted at lease expiry): without
        this, finished()/wait_all would hang on requests nobody owns."""
        for tid in self.repo.failed_tasks():
            with self._done_cond:
                rid = self._by_tid.get(tid)
                rec = self._records.get(rid) if rid is not None else None
                if (rec is not None and not rec.failed
                        and rec.tokens is None):
                    rec.failed = True
                    if rec.fail_reason is None:
                        rec.fail_reason = "attempt budget exhausted"
                    self._n_settled += 1
                    self._done_cond.notify_all()

    def pool_pressure(self) -> dict:
        """One-shot demand/supply snapshot for the autoscaler control loop:
        repo backlog (queued requests waiting for a server + leased
        in-flight), unsettled total, announced servers, pool-level TTFT
        percentiles over a bounded recent window (this runs every control
        tick — it must not sort the pool's full history), and the worst KV
        pressure / per-server blocked-admission counters across fresh
        server telemetry (stale entries — a dead server's last sample —
        are pruned here).  ``blocked_by_server`` carries the cumulative
        per-server counters so the autoscaler can diff per server: server
        churn (retire, TTL prune) must never fabricate or mask a delta in
        a fleet-wide sum.

        SICK servers (stall-benched) are counted in ``sick_servers`` and
        excluded from the capacity-side aggregates (``tokens_per_step``,
        ``acceptance_rate``, ``kv_memory_utilization``): a stalled pilot's
        last healthy-looking heartbeat must not keep propping up effective
        capacity — the autoscaler should scale UP around it."""
        now = time.monotonic()
        rs = self.repo.stats()
        with self._lock:
            pending = len(self._records) - self._n_settled
            for sid in [s for s, (t, _) in self._telemetry.items()
                        if now - t > self.telemetry_ttl]:
                del self._telemetry[sid]
            for sid in [s for s, u in self._sick.items() if now >= u]:
                del self._sick[sid]
            sick = set(self._sick)
            tele = {s: d for s, (_, d) in self._telemetry.items()}
            n_servers = len(self.servers)
            all_servers = set(self.servers)
            server_labels = dict(self._server_labels)
            ttfts = sorted(self._recent_ttfts)
            ttfts_by_label = {lab: sorted(d) for lab, d
                              in self._recent_ttfts_by_label.items()}
        n = len(ttfts)
        blocked = {s: int(d.get("blocked_admissions", 0))
                   for s, d in tele.items()}
        healthy = {s: d for s, d in tele.items() if s not in sick}
        # speculative-decoding effectiveness, averaged over the servers
        # that report it: tokens_per_step is the fleet's EFFECTIVE per-
        # pilot throughput (> slot count when draft acceptance is high),
        # which the autoscaler uses in place of nominal slot capacity
        acc = [float(d["acceptance_rate"]) for d in healthy.values()
               if "acceptance_rate" in d]
        tps = [float(d["tokens_per_step"]) for d in healthy.values()
               if "tokens_per_step" in d]
        # per-SERVER slot capacity: a mesh-bound (tensor-parallel) server
        # is ONE unit of `slots` capacity however many devices back it —
        # mesh_devices is reported for observability only and must never
        # multiply into the autoscaler's demand-proportional target
        srv_slots = [float(d["slots"]) for d in healthy.values()
                     if "slots" in d]

        # per-label split: a mixed prefill/decode fleet must not blend
        # prefill TTFT with decode TPOT (or one role's KV pressure with
        # the other's) — the autoscaler for each role reads its own slice
        def lab_of(s):
            return str(server_labels.get(s, {}).get("pool", "default"))

        by_label: dict[str, dict] = {}
        for lab in sorted({lab_of(s) for s in all_servers}
                          | set(ttfts_by_label)):
            srv = [s for s in all_servers if lab_of(s) == lab]
            h = {s: d for s, d in healthy.items() if lab_of(s) == lab}
            lt = ttfts_by_label.get(lab, [])
            m = len(lt)
            acc_l = [float(d["acceptance_rate"]) for d in h.values()
                     if "acceptance_rate" in d]
            tps_l = [float(d["tokens_per_step"]) for d in h.values()
                     if "tokens_per_step" in d]
            sl_l = [float(d["slots"]) for d in h.values() if "slots" in d]
            by_label[lab] = {
                "servers": len(srv),
                "sick_servers": sum(1 for s in srv if s in sick),
                "ttft_p50_s": lt[m // 2] if m else None,
                "ttft_p99_s": lt[min(m - 1, (99 * m) // 100)] if m else None,
                "kv_memory_utilization": max(
                    (d.get("kv_memory_utilization", 0.0)
                     for d in h.values()), default=0.0),
                "blocked_admissions": sum(
                    int(d.get("blocked_admissions", 0))
                    for s, d in tele.items() if lab_of(s) == lab),
                # per-server counters restricted to this label so a role's
                # autoscaler can diff per server without seeing the other
                # role's churn
                "blocked_by_server": {
                    s: int(d.get("blocked_admissions", 0))
                    for s, d in tele.items() if lab_of(s) == lab},
                "acceptance_rate": (sum(acc_l) / len(acc_l)
                                    if acc_l else 0.0),
                "tokens_per_step": sum(tps_l) / len(tps_l) if tps_l else 0.0,
                "slots_per_server": sum(sl_l) / len(sl_l) if sl_l else 0.0,
                "prefills_exported": sum(
                    int(d.get("prefills_exported", 0)) for d in h.values()),
                "handoffs_imported": sum(
                    int(d.get("handoffs_imported", 0)) for d in h.values()),
            }
        return {
            "by_label": by_label,
            "queued": rs["queued"],
            "leased": rs["leased"],
            "pending": pending,
            "servers": n_servers,
            "sick_servers": len(sick),
            "sealed": self.sealed.is_set(),
            "ttft_p50_s": ttfts[n // 2] if n else None,
            "ttft_p99_s": ttfts[min(n - 1, (99 * n) // 100)] if n else None,
            "kv_memory_utilization": max(
                (d.get("kv_memory_utilization", 0.0)
                 for d in healthy.values()), default=0.0),
            "blocked_admissions": sum(blocked.values()),
            "blocked_by_server": blocked,
            "acceptance_rate": sum(acc) / len(acc) if acc else 0.0,
            "tokens_per_step": sum(tps) / len(tps) if tps else 0.0,
            "slots_per_server": (sum(srv_slots) / len(srv_slots)
                                 if srv_slots else 0.0),
            "mesh_devices": max(
                (int(d.get("mesh_devices", 1)) for d in healthy.values()),
                default=1),
        }

    def lease_holders(self) -> dict[str, list[int]]:
        """server_id -> rids it currently holds leases for (the failure
        driver picks its victim here)."""
        out: dict[str, list[int]] = {}
        with self._lock:
            for (server, rid) in self._leased:
                out.setdefault(server, []).append(rid)
        return out

    def results(self) -> dict[int, list]:
        """rid -> accepted token list, completed requests only."""
        with self._lock:
            return {rid: list(rec.tokens)
                    for rid, rec in self._records.items()
                    if rec.tokens is not None}

    def records(self) -> dict[int, RequestRecord]:
        with self._lock:
            return dict(self._records)

    def stats(self) -> dict:
        with self._lock:
            recs = list(self._records.values())
            completed = [r for r in recs if r.tokens is not None]
            return {
                "requests": len(recs),
                "completed": len(completed),
                "failed": sum(1 for r in recs if r.failed),
                "duplicates": self.duplicates,
                "lost_leases": self.lost_leases,
                # replays: extra dispatches beyond the first — the price of
                # the failures, not of the steady state
                "replays": sum(max(0, r.attempts - 1) for r in recs),
                "distinct_servers": len({r.server for r in completed}),
                "hedges": self.hedges,
                "stalls_revoked": self.stalls_revoked,
                "quarantined": self.quarantined,
            }

    def close(self):
        """Unregister the pool and release any server parked in fetch."""
        self.closed.set()
        if self._watchdog_timer is not None:
            self._watchdog_timer.cancel()
            self._watchdog_timer = None
        with _POOLS_LOCK:
            _POOLS.pop(self.name, None)
        self.repo.kick()


class DisaggRouter:
    """Two-stage request router for disaggregated prefill/decode fleets.

    One request flows through TWO pools, each an ordinary
    :class:`FleetDispatcher` with its own leases, reaper, robustness
    policy and telemetry:

    1. ``submit`` queues the prompt into the **prefill** pool.  A
       prefill-role server leases it, runs admission, and completes with
       the one admission token plus a
       :class:`~repro.serving.blockpool.KVHandoff`.
    2. The prefill pool's accepted completion fires ``on_complete``
       (exactly once per rid, however many replays raced), and the
       router resubmits into the **decode** pool — the entry carries the
       handoff object by reference (pool entries never serialize — the
       in-memory arena idiom) and the ORIGINAL ``submitted_s``, so
       decode-pool TTFT remains end-to-end.
    3. A decode-role server leases it, scatters the handoff into its own
       pool, and streams the remaining tokens.

    Failure semantics fall out of the per-stage lease machinery:

    * a dead PREFILL pilot stops renewing -> the prefill repo requeues
      the PROMPT; the survivor replays admission (deterministic) and its
      accepted completion forwards the handoff once;
    * a dead DECODE pilot stops renewing -> the decode repo requeues the
      ENTRY — which still carries the handoff — so the survivor replays
      from the HANDOFF, never re-prefilling the prompt.

    ``results()`` returns the full streams (decode-stage results, plus
    any prefill-only completion that never forwarded — e.g. quarantined
    before the decode stage existed)."""

    def __init__(self, *, name: str | None = None, lease_ttl: float = 1.0,
                 max_attempts: int = 8,
                 policy: RobustnessPolicy | None = None):
        base = name or f"disagg-{uuid.uuid4().hex[:8]}"
        self.name = base
        self.prefill = FleetDispatcher(
            name=f"{base}-prefill", lease_ttl=lease_ttl,
            max_attempts=max_attempts, policy=policy)
        self.decode = FleetDispatcher(
            name=f"{base}-decode", lease_ttl=lease_ttl,
            max_attempts=max_attempts, policy=policy)
        self.prefill.on_complete = self._forward
        self._fwd_lock = make_lock("dispatch.router-fwd")
        self._forwarded: set[int] = set()

    # ---- stage 1 -> stage 2 ------------------------------------------------

    def _forward(self, rec: RequestRecord, handoff):
        """Forward an accepted prefill completion into the decode pool.
        Runs outside the prefill pool's lock (its ``on_complete``
        contract); `complete` already guarantees one accepted winner per
        rid, and the `_forwarded` set makes the forward idempotent even
        against a buggy double-callback."""
        if handoff is None:
            return                      # settled without a handoff: final
        with self._fwd_lock:
            if rec.rid in self._forwarded:
                return
            self._forwarded.add(rec.rid)
        entry = dict(rec.entry)
        entry.update(
            rid=rec.rid,
            handoff=handoff,
            submitted_s=rec.submitted_s,       # end-to-end TTFT zero
            prefill_first_token_s=rec.first_token_s,
            prefill_server=rec.server)
        self.decode.submit(entry)

    # ---- driver side -------------------------------------------------------

    def submit(self, entry: dict) -> int:
        return self.prefill.submit(entry)

    def submit_trace(self, trace: list[dict]) -> list[int]:
        return [self.submit(e) for e in trace]

    def seal(self):
        """Seal the PREFILL stage only: the decode stage stays open for
        forwards until every prefill settles (`wait_all` seals it)."""
        self.prefill.seal()

    def wait_all(self, timeout: float | None = None) -> bool:
        """Prefill settles -> no more forwards are coming -> seal decode
        -> decode settles."""
        t0 = time.monotonic()
        if not self.prefill.wait_all(timeout):
            return False
        self.decode.seal()
        left = (None if timeout is None
                else max(0.0, timeout - (time.monotonic() - t0)))
        return self.decode.wait_all(left)

    def finished(self) -> bool:
        if not self.prefill.finished():
            return False
        self.decode.seal()
        return self.decode.finished()

    def results(self) -> dict[int, list]:
        out = {rid: toks for rid, toks in self.prefill.results().items()
               if rid not in self._forwarded}
        out.update(self.decode.results())
        return out

    def records(self) -> dict[str, dict[int, RequestRecord]]:
        return {"prefill": self.prefill.records(),
                "decode": self.decode.records()}

    def stats(self) -> dict:
        return {"prefill": self.prefill.stats(),
                "decode": self.decode.stats()}

    def pool_pressure(self) -> dict:
        return {"prefill": self.prefill.pool_pressure(),
                "decode": self.decode.pool_pressure()}

    def close(self):
        self.prefill.close()
        self.decode.close()
