"""FleetDispatcher — a fleet-wide serve request pool (requeue-on-pilot-failure).

The single-engine serve path binds one request *trace* to one engine: if
that engine's pilot dies, its in-flight requests die with it.  The fleet
dispatcher is the late-binding analog of task requeue applied to SERVING
(paper §3.4/§3.6: the slice claim outlives the payload, but resource
*ownership* churns):

* a request trace is split into per-request entries in a dedicated
  :class:`~repro.core.taskrepo.TaskRepo` — same leases, same matchmaking
  index, same deadline-heap reaper that already makes dead pilots harmless
  for batch tasks;
* serving pilots LEASE requests (:meth:`fetch`) into free engine slots and
  piggyback per-request progress on lease renewal (:meth:`renew`) every
  engine tick;
* a pilot that dies simply stops renewing: the repo's lease-expiry reaper
  requeues its in-flight requests and wakes any surviving server parked in
  ``fetch`` — the survivor replays them from the prompt (greedy decode over
  slot-isolated state is deterministic, so the replayed tokens are bitwise
  the tokens the dead pilot would have produced);
* completion is EXACTLY ONCE per request id: :meth:`complete` routes
  through ``TaskRepo.complete`` (first completion wins), so a slow original
  server racing a replayed copy produces one accepted result and one
  counted duplicate — never two.

Request lease lifecycle::

    submit ──> queued ──> leased(server A) ──renew──> ... ──> completed
                  ^            │ no renew (A died)                 ^
                  └── requeued ┘ after lease_ttl                   │
                  └────────────── leased(server B), replay ────────┘

Pools register under a process-global name (the simulation's stand-in for
a network endpoint): a serve payload finds its pool with
:func:`get_pool(spec["dispatch"])` from inside the payload container.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import uuid
from collections import deque

from repro.core.taskrepo import TaskRepo, TaskResult

_POOLS: dict[str, "FleetDispatcher"] = {}
_POOLS_LOCK = threading.Lock()


def get_pool(name: str) -> "FleetDispatcher | None":
    """Resolve a pool name published in a serve payload's startup spec."""
    with _POOLS_LOCK:
        return _POOLS.get(name)


@dataclasses.dataclass
class RequestRecord:
    """Dispatcher-side state of one request across its (re)dispatches."""
    rid: int
    task_id: int
    entry: dict                         # the JSON-able request body
    submitted_s: float                  # monotonic submit time (TTFT zero)
    tokens: list | None = None          # accepted completion (first wins)
    server: str | None = None           # the server whose result won
    first_token_s: float | None = None  # pool-level TTFT (includes requeue)
    completed_s: float | None = None
    attempts: int = 0                   # dispatches (>1 == replayed)
    progress: int = 0                   # tokens reported via renew()
    failed: bool = False                # rejected max_attempts times
    servers_tried: list = dataclasses.field(default_factory=list)


class FleetDispatcher:
    def __init__(self, *, name: str | None = None, lease_ttl: float = 1.0,
                 max_attempts: int = 8):
        self.name = name or f"pool-{uuid.uuid4().hex[:8]}"
        # a DEDICATED repo: request leases expire on their own (short) TTL,
        # independent of the pilot-level task leases
        self.repo = TaskRepo(lease_ttl=lease_ttl)
        self.max_attempts = max_attempts
        self._lock = threading.Lock()
        self._done_cond = threading.Condition(self._lock)
        self._records: dict[int, RequestRecord] = {}
        self._by_tid: dict[int, int] = {}
        # (server_id, rid) -> leased PayloadTask (needed for release/renew)
        self._leased: dict[tuple[str, int], object] = {}
        self._n_settled = 0               # completed + failed
        self.duplicates = 0               # completions dropped by first-wins
        self.lost_leases = 0              # renewals refused (re-leased away)
        self.servers: set[str] = set()    # servers that announced readiness
        # server_id -> (monotonic stamp, engine telemetry sample): the
        # per-tick KV-pressure heartbeat the autoscaler reads; entries
        # go stale after telemetry_ttl (a dead server stops reporting)
        self._telemetry: dict[str, tuple[float, dict]] = {}
        self.telemetry_ttl = max(5.0 * lease_ttl, 2.0)
        # bounded recent-TTFT window so pool_pressure (called every
        # autoscaler tick) never sorts the pool's full request history
        self._recent_ttfts: deque[float] = deque(maxlen=2048)
        self.sealed = threading.Event()   # no further submissions coming
        self.closed = threading.Event()
        with _POOLS_LOCK:
            _POOLS[self.name] = self

    # ---- submission -------------------------------------------------------

    def submit(self, entry: dict) -> int:
        """Queue one request.  ``entry`` is the trace-entry format
        (``{"rid", "prompt": [ints], "max_new_tokens", ...}``); an optional
        ``require_labels`` dict rides into the repo's matchmaking index so a
        request can be pinned to servers advertising matching labels (e.g.
        one pool feeding several model fleets)."""
        rid = int(entry["rid"])
        if self.sealed.is_set():
            raise RuntimeError(f"pool {self.name} is sealed")
        # record BEFORE publishing: the repo submit wakes parked fetchers,
        # which must always find the record.  The tid->rid mapping may lag
        # by microseconds; fetch falls back to the rid the task itself
        # carries in its payload_spec.
        rec = RequestRecord(rid=rid, task_id=-1, entry=dict(entry),
                            submitted_s=time.monotonic())
        with self._lock:
            if rid in self._records:
                raise ValueError(f"duplicate request id {rid}")
            self._records[rid] = rec
        tid = self.repo.submit(
            "serve-request",
            require_labels=entry.get("require_labels"),
            priority=int(entry.get("priority", 0)),
            max_attempts=self.max_attempts,
            payload_spec={"rid": rid})
        with self._lock:
            rec.task_id = tid
            self._by_tid[tid] = rid
        return rid

    def submit_trace(self, trace: list[dict]) -> list[int]:
        """Split a request trace into per-request pool entries.  Arrival
        staggering (``at_step``) is an engine-tick concept and is ignored
        here — fleet arrivals are wall-clock submissions."""
        return [self.submit(e) for e in trace]

    # ---- the server side (called from serve payloads) ---------------------

    def announce(self, server_id: str):
        """A server reports it is up and WARM (engine compiled, ready to
        lease).  Drivers that want cold-start excluded from TTFT wait for
        the fleet with :meth:`wait_servers` before submitting traffic."""
        with self._done_cond:
            self.servers.add(server_id)
            self._done_cond.notify_all()

    def wait_servers(self, n: int, timeout: float | None = None) -> bool:
        return self._wait_for(lambda: len(self.servers) >= n, timeout)

    def retire(self, server_id: str):
        """A server's graceful exit (scale-down drain, tick budget, pool
        finished): drop it from the announced set and forget its telemetry,
        so pool pressure never counts capacity that is gone."""
        with self._done_cond:
            self.servers.discard(server_id)
            self._telemetry.pop(server_id, None)
            self._done_cond.notify_all()

    def report_telemetry(self, server_id: str, sample: dict):
        """Per-tick engine telemetry heartbeat (kv_memory_utilization,
        blocked_admissions, free_slots, ...) — the demand-side signal the
        autoscaler folds into its scale decisions."""
        with self._lock:
            self._telemetry[server_id] = (time.monotonic(), dict(sample))

    def fetch(self, server_id: str, *, max_n: int = 1, timeout: float = 0.0,
              labels: dict | None = None, cancel=None) -> list[dict]:
        """Lease up to ``max_n`` requests for this server.  The first match
        may block up to ``timeout`` (parked on the repo condition — a
        requeued request wakes it immediately); the rest are non-blocking.
        Returned entries carry ``rid``, ``submitted_s`` (the pool-level TTFT
        zero) and ``attempt``."""
        ad = {"pilot_id": server_id, "labels": dict(labels or {})}
        stop = (self.closed.is_set if cancel is None
                else lambda: self.closed.is_set() or cancel())
        out: list[dict] = []
        for i in range(max_n):
            if i == 0 and timeout > 0:
                task = self.repo.match_wait(ad, timeout=timeout, cancel=stop)
            else:
                task = self.repo.match(ad)
            if task is None:
                break
            with self._lock:
                # the submitter records the task before publishing but may
                # not have written the tid mapping yet — the task's own
                # payload_spec always carries the rid
                rid = self._by_tid.get(task.task_id)
                if rid is None:
                    rid = int(task.payload_spec["rid"])
                    self._by_tid[task.task_id] = rid
                rec = self._records[rid]
                rec.task_id = task.task_id
                if rec.tokens is not None or rec.failed:
                    # stale queued copy of an already-settled request (its
                    # lease expired in the same window the original server
                    # finished, or it settled as failed).  failed=rec.failed
                    # routes the failed case into the repo's _failed state
                    # instead of re-enqueueing a zombie that would win every
                    # future match (lowest task_id) and starve the queue.
                    self.repo.release(task, failed=rec.failed,
                                      pilot_id=server_id)
                    continue
                # the previous holder is dead or lost the lease — its stale
                # lease record must not keep counting it as a holder
                for k in [k for k in self._leased
                          if k[1] == rid and k[0] != server_id]:
                    del self._leased[k]
                self._leased[(server_id, rid)] = task
                rec.attempts = task.attempts
                rec.servers_tried.append(server_id)
                e = dict(rec.entry)
                e["rid"] = rid
                e["submitted_s"] = rec.submitted_s
                e["attempt"] = task.attempts
            out.append(e)
        return out

    def renew(self, server_id: str, progress: dict[int, int]) -> list[int]:
        """Renew this server's request leases, piggybacking per-request
        progress (tokens produced so far) on the heartbeat.  Returns the
        rids whose lease this server NO LONGER holds (expired and re-leased
        or requeued) — the caller should ``ServeEngine.cancel`` them instead
        of burning slots on tokens that can never win."""
        lost: list[int] = []
        for rid, n_tokens in progress.items():
            with self._lock:
                task = self._leased.get((server_id, rid))
                rec = self._records.get(rid)
            if task is None or rec is None:
                # the lease record was already swept (the rid re-leased to
                # another server, or the pool never knew it) — still a loss
                # from this server's point of view
                if rec is not None and rec.tokens is None:
                    self.lost_leases += 1
                lost.append(rid)
                continue
            if self.repo.renew(task.task_id, server_id):
                with self._lock:
                    rec.progress = max(rec.progress, int(n_tokens))
            else:
                lost.append(rid)
                self.lost_leases += 1
                with self._lock:
                    self._leased.pop((server_id, rid), None)
        return lost

    def complete(self, server_id: str, rid: int, tokens: list,
                 *, first_token_s: float | None = None) -> bool:
        """Report a finished request.  First completion wins — routed
        through ``TaskRepo.complete``'s result dedup, so a replayed copy
        racing the original produces exactly one accepted result."""
        with self._lock:
            rec = self._records.get(rid)
        if rec is None:
            return False
        accepted = self.repo.complete(TaskResult(
            task_id=rec.task_id, pilot_id=server_id, exitcode=0,
            telemetry={"rid": rid, "n_tokens": len(tokens)}))
        with self._done_cond:
            self._leased.pop((server_id, rid), None)
            # a request settles EXACTLY once: a late result for a request
            # that already settled as failed (reject path) must not bump
            # _n_settled a second time — that would let wait_all/finished
            # fire with other work still in flight
            if accepted and not rec.failed and rec.tokens is None:
                rec.tokens = list(tokens)
                rec.server = server_id
                rec.first_token_s = first_token_s
                if first_token_s is not None:
                    self._recent_ttfts.append(first_token_s)
                rec.completed_s = time.monotonic() - rec.submitted_s
                self._n_settled += 1
                self._done_cond.notify_all()
            else:
                self.duplicates += 1
                accepted = False
        return accepted

    def release(self, server_id: str, rids: list[int]):
        """Hand leased-but-unfinished requests straight back (graceful
        payload end / drain): they requeue immediately instead of waiting
        out the lease TTL."""
        for rid in rids:
            with self._lock:
                task = self._leased.pop((server_id, rid), None)
            if task is not None:
                # pilot_id guard: if the lease already expired and moved,
                # the new holder's lease survives and nothing is duplicated
                self.repo.release(task, pilot_id=server_id)

    def reject(self, server_id: str, rid: int):
        """This server can never run the request (e.g. the prompt exceeds
        its engine's max_len).  The request retries elsewhere until the
        pool's ``max_attempts``, then settles as failed — it must not
        ping-pong forever between release and fetch."""
        with self._lock:
            task = self._leased.pop((server_id, rid), None)
            rec = self._records.get(rid)
        if task is None or rec is None:
            return
        self.repo.release(task, failed=True, pilot_id=server_id)
        if task.attempts >= self.max_attempts:
            with self._done_cond:
                if not rec.failed and rec.tokens is None:
                    rec.failed = True
                    self._n_settled += 1
                    self._done_cond.notify_all()

    # ---- driver side ------------------------------------------------------

    def seal(self):
        """Declare that no further requests will be submitted.  Servers
        keep serving a momentarily-drained pool (elastic traffic!) until it
        is sealed AND everything has settled — only then does
        :meth:`finished` let them exit."""
        self.sealed.set()
        with self._done_cond:
            self._done_cond.notify_all()

    def finished(self) -> bool:
        """True once the pool is sealed and every submitted request has
        settled (completed or failed).  An unsealed pool is never finished
        — more traffic may arrive, servers park in fetch."""
        if not self.sealed.is_set():
            return False
        self._absorb_repo_failures()
        with self._lock:
            return self._n_settled == len(self._records)

    def wait_all(self, timeout: float | None = None) -> bool:
        """Block until every submitted request settles."""
        return self._wait_for(
            lambda: bool(self._records)
            and self._n_settled == len(self._records), timeout)

    def wait_completed(self, n: int, timeout: float | None = None) -> bool:
        """Block until at least ``n`` requests have settled — the hook a
        failure-injection driver uses to kill a pilot MID-trace."""
        return self._wait_for(lambda: self._n_settled >= n, timeout)

    def _wait_for(self, pred, timeout: float | None) -> bool:
        """Condition-wait for ``pred`` (evaluated under the pool lock).
        The wait is bounded to short slices so repo-level settlements that
        bypass the pool's notifications (the reaper failing a request whose
        attempt budget died with a lease) are absorbed promptly."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            self._absorb_repo_failures()
            with self._done_cond:
                if pred():
                    return True
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return False
                self._done_cond.wait(
                    timeout=0.25 if remaining is None
                    else min(0.25, remaining))

    def _absorb_repo_failures(self):
        """Settle records whose repo task failed without any server
        reporting it (attempt budget exhausted at lease expiry): without
        this, finished()/wait_all would hang on requests nobody owns."""
        for tid in self.repo.failed_tasks():
            with self._done_cond:
                rid = self._by_tid.get(tid)
                rec = self._records.get(rid) if rid is not None else None
                if (rec is not None and not rec.failed
                        and rec.tokens is None):
                    rec.failed = True
                    self._n_settled += 1
                    self._done_cond.notify_all()

    def pool_pressure(self) -> dict:
        """One-shot demand/supply snapshot for the autoscaler control loop:
        repo backlog (queued requests waiting for a server + leased
        in-flight), unsettled total, announced servers, pool-level TTFT
        percentiles over a bounded recent window (this runs every control
        tick — it must not sort the pool's full history), and the worst KV
        pressure / per-server blocked-admission counters across fresh
        server telemetry (stale entries — a dead server's last sample —
        are pruned here).  ``blocked_by_server`` carries the cumulative
        per-server counters so the autoscaler can diff per server: server
        churn (retire, TTL prune) must never fabricate or mask a delta in
        a fleet-wide sum."""
        now = time.monotonic()
        rs = self.repo.stats()
        with self._lock:
            pending = len(self._records) - self._n_settled
            for sid in [s for s, (t, _) in self._telemetry.items()
                        if now - t > self.telemetry_ttl]:
                del self._telemetry[sid]
            tele = {s: d for s, (_, d) in self._telemetry.items()}
            n_servers = len(self.servers)
            ttfts = sorted(self._recent_ttfts)
        n = len(ttfts)
        blocked = {s: int(d.get("blocked_admissions", 0))
                   for s, d in tele.items()}
        # speculative-decoding effectiveness, averaged over the servers
        # that report it: tokens_per_step is the fleet's EFFECTIVE per-
        # pilot throughput (> slot count when draft acceptance is high),
        # which the autoscaler uses in place of nominal slot capacity
        acc = [float(d["acceptance_rate"]) for d in tele.values()
               if "acceptance_rate" in d]
        tps = [float(d["tokens_per_step"]) for d in tele.values()
               if "tokens_per_step" in d]
        return {
            "queued": rs["queued"],
            "leased": rs["leased"],
            "pending": pending,
            "servers": n_servers,
            "sealed": self.sealed.is_set(),
            "ttft_p50_s": ttfts[n // 2] if n else None,
            "ttft_p99_s": ttfts[min(n - 1, (99 * n) // 100)] if n else None,
            "kv_memory_utilization": max(
                (d.get("kv_memory_utilization", 0.0)
                 for d in tele.values()), default=0.0),
            "blocked_admissions": sum(blocked.values()),
            "blocked_by_server": blocked,
            "acceptance_rate": sum(acc) / len(acc) if acc else 0.0,
            "tokens_per_step": sum(tps) / len(tps) if tps else 0.0,
        }

    def lease_holders(self) -> dict[str, list[int]]:
        """server_id -> rids it currently holds leases for (the failure
        driver picks its victim here)."""
        out: dict[str, list[int]] = {}
        with self._lock:
            for (server, rid) in self._leased:
                out.setdefault(server, []).append(rid)
        return out

    def results(self) -> dict[int, list]:
        """rid -> accepted token list, completed requests only."""
        with self._lock:
            return {rid: list(rec.tokens)
                    for rid, rec in self._records.items()
                    if rec.tokens is not None}

    def records(self) -> dict[int, RequestRecord]:
        with self._lock:
            return dict(self._records)

    def stats(self) -> dict:
        with self._lock:
            recs = list(self._records.values())
            completed = [r for r in recs if r.tokens is not None]
            return {
                "requests": len(recs),
                "completed": len(completed),
                "failed": sum(1 for r in recs if r.failed),
                "duplicates": self.duplicates,
                "lost_leases": self.lost_leases,
                # replays: extra dispatches beyond the first — the price of
                # the failures, not of the steady state
                "replays": sum(max(0, r.attempts - 1) for r in recs),
                "distinct_servers": len({r.server for r in completed}),
            }

    def close(self):
        """Unregister the pool and release any server parked in fetch."""
        self.closed.set()
        with _POOLS_LOCK:
            _POOLS.pop(self.name, None)
        self.repo.kick()
