from repro.serving.engine import Request, ServeEngine  # noqa: F401
