from repro.serving.blockpool import BlockAllocator, PrefixCache  # noqa: F401
from repro.serving.dispatch import FleetDispatcher, get_pool  # noqa: F401
from repro.serving.engine import Request, ServeEngine  # noqa: F401
