"""Host-side block allocator + prefix cache for the paged KV serve path.

The device holds one block pool per attention layer, all indexed by the
SAME physical block ids; the allocator hands out ids, so one host-side
free list manages every layer's memory at once.  Block 0 is reserved as
the scratch block: freed slots keep decoding (cheaper than masking the
batched matmuls) and their garbage writes land there, never in a live
request's blocks.

Invariants (tested in tests/test_paged_kv.py):

* a refcount never goes negative — double-free raises;
* a block returns to the free list exactly when its refcount hits 0, so
  evicting a request returns every block it exclusively owned;
* prefix-shared blocks are copy-on-write safe BY CONSTRUCTION: only FULL
  blocks strictly below the admitted prompt's write frontier are ever
  shared, and both decode and chunked prefill write at positions at or
  beyond that frontier — a shared block is never a write target, so no
  copy is ever needed (sharing is a block-table entry + a refcount bump);
* the engine allocates a request's worst-case reach (prompt + budget,
  capped at max_len) at admission, so decode can never fail mid-flight.

The prefix cache is hash-keyed per model image (each engine owns its
allocator, and the chain hash covers the exact padded token bytes), maps
``hash(padded_tokens[: (j+1) * block_size])`` to the physical block
holding positions ``[j*bs, (j+1)*bs)``, and holds one reference on every
published block so prefixes outlive their first request.  Under pool
pressure, unreferenced prefix blocks (refcount 1 — cache-only) are
evicted oldest-first.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np


class BlockAllocator:
    """Free-list + refcount allocator over ``num_blocks`` physical blocks
    (block 0 reserved as scratch, never handed out)."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2, "need at least scratch + one real block"
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))     # LIFO
        self._refs = np.zeros(num_blocks, np.int32)

    # -- capacity ------------------------------------------------------

    @property
    def capacity_blocks(self) -> int:
        return self.num_blocks - 1                          # minus scratch

    @property
    def capacity_tokens(self) -> int:
        return self.capacity_blocks * self.block_size

    @property
    def allocated_blocks(self) -> int:
        return self.capacity_blocks - len(self._free)

    @property
    def available_blocks(self) -> int:
        return len(self._free)

    # -- alloc / share / free ------------------------------------------

    def alloc(self) -> int:
        """Pop a free block (refcount 1)."""
        if not self._free:
            raise RuntimeError("block pool exhausted")
        bid = self._free.pop()
        self._refs[bid] = 1
        return bid

    def share(self, bid: int) -> int:
        """Bump a live block's refcount (prefix reuse)."""
        assert self._refs[bid] > 0, f"share of dead block {bid}"
        self._refs[bid] += 1
        return bid

    def free(self, bid: int):
        """Drop one reference; the block returns to the free list at 0."""
        if bid == 0:
            return                                          # scratch
        if self._refs[bid] <= 0:
            raise RuntimeError(f"refcount underflow on block {bid}")
        self._refs[bid] -= 1
        if self._refs[bid] == 0:
            self._free.append(bid)

    def refcount(self, bid: int) -> int:
        return int(self._refs[bid])


class PrefixCache:
    """Chain-hash -> physical block map for full prompt blocks.

    Keys cover the exact PADDED token bytes up to each block boundary, so
    a hit guarantees bit-identical KV content (positions and tokens both
    match).  The cache holds one reference per published block; evicting
    an entry drops that reference."""

    def __init__(self, alloc: BlockAllocator):
        self._alloc = alloc
        self._map: OrderedDict[bytes, int] = OrderedDict()  # key -> bid
        self.lookups = 0
        self.hits = 0

    @staticmethod
    def block_keys(padded_tokens: np.ndarray, block_size: int,
                   n_blocks: int) -> list[bytes]:
        """Chain-hash keys for the first ``n_blocks`` FULL blocks of a
        padded prompt: key_j = H(tokens[: (j+1) * bs])."""
        toks = np.ascontiguousarray(padded_tokens, np.int32)
        return [hashlib.sha1(toks[: (j + 1) * block_size].tobytes()).digest()
                for j in range(n_blocks)]

    def match(self, keys: list[bytes]) -> list[int]:
        """Longest-prefix match: returns the physical ids of the leading
        blocks already cached (refcounts bumped — caller owns one ref per
        returned block)."""
        out = []
        for key in keys:
            self.lookups += 1
            bid = self._map.get(key)
            if bid is None:
                break
            self.hits += 1
            out.append(self._alloc.share(bid))
        return out

    def publish(self, key: bytes, bid: int):
        """Register a freshly-filled full block (cache takes one ref)."""
        if key in self._map:
            return                                          # raced: keep first
        self._map[key] = self._alloc.share(bid)
        self._map.move_to_end(key)

    def evict_unreferenced(self, want_blocks: int) -> int:
        """Drop oldest cache-only entries (refcount 1) until
        ``want_blocks`` are freed or nothing evictable remains."""
        freed = 0
        for key in list(self._map):
            if freed >= want_blocks:
                break
            bid = self._map[key]
            if self._alloc.refcount(bid) == 1:              # cache-only
                del self._map[key]
                self._alloc.free(bid)
                freed += 1
        return freed

    def clear(self):
        for key, bid in list(self._map.items()):
            self._alloc.free(bid)
        self._map.clear()

    def __len__(self):
        return len(self._map)


# --------------------------------------------------------------------------
# KV block handoff: the disaggregated prefill -> decode wire format
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVHandoff:
    """A finished prompt's KV, packaged for import into ANOTHER engine's
    block pool — the payload a prefill pilot hands to the decode fleet.

    ``blocks`` is one dict per attention layer, each mapping a paged pool
    key (``kp``/``vp`` for GQA, ``ckvp``/``kropep`` for MLA) to a host
    buffer of shape ``(groups, n_prompt_blocks, block_size, ...)`` — the
    slot's block chain gathered contiguously (device-side gather, one
    host pull for the whole pytree).  Because chunk boundaries, padding
    and bucket shapes are identical on both sides, scattering these
    buffers into the importer's pool reproduces the exporter's KV rows
    bit for bit.

    ``block_hashes`` carries the exporter's chain-hash keys over the
    padded prompt, so the importer can (a) skip scattering blocks its own
    :class:`PrefixCache` already holds and (b) republish the fresh full
    blocks under the SAME keys — prefix sharing survives the handoff.

    ``fingerprint`` pins everything the scatter relies on (block size
    plus every paged leaf's pool layout and dtype); an importer whose
    pools disagree must reject the handoff rather than write garbage.

    ``first_token`` is the admission-time argmax — the one token prefill
    produced.  A decode engine that installs ``pos = plen``, ``token =
    first_token`` and the scattered blocks holds EXACTLY the state a
    unified engine holds after admission, which is why the resumed greedy
    stream is bitwise identical (DESIGN.md "Disaggregated prefill/decode").
    """

    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32, unpadded
    plen: int                          # admission bucket (padded length)
    first_token: int                   # argmax of the prefill logits
    max_new_tokens: int                # decode budget riding along
    block_hashes: tuple                # chain-hash keys, one per FULL block
    fingerprint: tuple                 # (block_size, per-layer pool layout)
    blocks: list                       # per-layer {key: np.ndarray} buffers

    @property
    def n_prompt_blocks(self) -> int:
        bs = self.fingerprint[0]
        return -(-self.plen // bs)

    @property
    def nbytes(self) -> int:
        """Handoff wire size: what actually crosses pools per request."""
        return sum(int(buf.nbytes)
                   for leaf in self.blocks for buf in leaf.values())

    def validate_against(self, fingerprint: tuple):
        """Raise unless the importer's pools can hold these buffers."""
        if fingerprint != self.fingerprint:
            raise ValueError(
                f"handoff fingerprint mismatch for rid {self.rid}: "
                f"exporter {self.fingerprint!r} vs importer "
                f"{fingerprint!r}; prefill and decode images must share "
                f"the arch, block size and KV dtype")
