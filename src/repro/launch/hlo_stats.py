"""Extract roofline terms from a compiled dry-run artifact.

``cost_analysis()`` supplies HLO_FLOPs / HLO_bytes (per-device, post-SPMD).
Collective bytes are NOT in cost_analysis, so we parse the partitioned HLO
text: every instruction line is ``%name = TYPE opcode(%operand, ...)``; we
index result types by name so collective operand sizes can be resolved.

Byte-counting conventions (per device, recorded per op kind):

* all-gather          -> result bytes (ring: each chip passes ~the full
                          gathered tensor through its link)
* all-reduce          -> 2 x result bytes (reduce-scatter + all-gather phases)
* reduce-scatter      -> operand bytes (full pre-reduction tensor streams by)
* all-to-all          -> result bytes
* collective-permute  -> result bytes

The §Roofline collective term is then  sum(weighted bytes) / ICI_BW  —
algebraically identical to the assignment's
``collective_bytes / (chips x link_bw)`` with collective_bytes summed over
all chips of the SPMD program.
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8,
    "s64": 8, "u64": 8, "f64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcode -> (use operand bytes?, multiplier)
_WEIGHT = {
    "all-gather": (False, 1.0),
    "all-reduce": (False, 2.0),
    "reduce-scatter": (True, 1.0),
    "all-to-all": (False, 1.0),
    "collective-permute": (False, 1.0),
}


def type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        size = _DTYPE_BYTES.get(dt)
        if size is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * size
    return total


def _split_type_op(rhs: str):
    """rhs: 'TYPE opcode(...)' -> (type_str, opcode) or None."""
    # TYPE is either '(...)' tuple or a token like 'bf16[8,16]{1,0}'
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                type_str = rhs[: i + 1]
                rest = rhs[i + 1:].strip()
                break
        else:
            return None
    else:
        parts = rhs.split(None, 1)
        if len(parts) != 2:
            return None
        type_str, rest = parts
    op = rest.split("(", 1)[0].strip()
    return type_str, op


_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def collective_stats(hlo_text: str) -> dict:
    """Parse HLO text -> {"counts": {op: n}, "bytes": {op: weighted_bytes},
    "total_bytes": float, "raw_bytes": {op: result_bytes}}."""
    types: dict[str, str] = {}
    collect_lines: list[tuple[str, str, str]] = []   # (name, type, full rhs)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        st = _split_type_op(rhs)
        if st is None:
            continue
        type_str, op = st
        types[name] = type_str
        base_op = op.split(".")[0]          # e.g. all-gather-start
        for c in COLLECTIVES:
            if base_op == c or base_op == c + "-start":
                collect_lines.append((name, c, rhs))
                break

    counts: dict[str, int] = {c: 0 for c in COLLECTIVES}
    weighted: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    raw: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    for name, c, rhs in collect_lines:
        st = _split_type_op(rhs)
        result_bytes = type_bytes(st[0])
        # -start ops wrap results in a tuple (operand, result[, scratch]);
        # count the real payload once.
        if "-start" in rhs.split("(", 1)[0]:
            result_bytes = result_bytes / 2
        use_operand, mult = _WEIGHT[c]
        nbytes = result_bytes
        if use_operand:
            args = rhs.split("(", 1)[1] if "(" in rhs else ""
            op_bytes = 0
            for om in _OPERAND_RE.finditer(args.split(")")[0]):
                t = types.get(om.group(1))
                if t is not None:
                    op_bytes += type_bytes(t)
            nbytes = op_bytes or result_bytes
        counts[c] += 1
        raw[c] += result_bytes
        weighted[c] += mult * nbytes
    return {
        "counts": {k: v for k, v in counts.items() if v},
        "bytes": {k: v for k, v in weighted.items() if v},
        "raw_bytes": {k: v for k, v in raw.items() if v},
        "total_bytes": sum(weighted.values()),
    }


def cost_summary(compiled) -> dict:
    """Pull flops / bytes out of compiled.cost_analysis() (per-device)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:       # noqa: BLE001
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # per-memory-space byte entries (bytes accessed0{}, operand 0 etc.)
    return out


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:       # noqa: BLE001
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_nonalias_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - 2 * out.get("alias_size_in_bytes", 0))
    return out
