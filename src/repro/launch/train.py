"""End-to-end training driver THROUGH the pilot system.

The canonical production invocation (paper lifecycle a-h, late binding,
checkpoint/restart, monitoring) on synthetic data:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 300 \
      --batch 8 --seq 512 --ckpt /tmp/ck [--smoke] [--direct]

``--direct`` bypasses the pilot system for a plain jit loop (useful for
debugging / perf A-B).  With ``--fail-at N`` a simulated node failure kills
the first pilot mid-run; the lease expires, a replacement pilot picks the
task up and resumes from the last checkpoint — the fault-tolerance demo.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from repro.configs.base import get_config, get_smoke_config
from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.core.taskrepo import TaskRepo
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.adamw import OptimConfig


def train_direct(cfg, steps: int, batch: int, seq: int, *, log_every=10):
    import jax.numpy as jnp
    step_fn = jax.jit(make_train_step(cfg, OptimConfig(
        total_steps=steps, warmup_steps=max(steps // 20, 5))),
        donate_argnums=0)
    state = init_train_state(cfg, jax.random.key(0))
    data = SyntheticLM(SyntheticConfig(cfg.vocab_size, seq, batch))
    losses = []
    t0 = time.monotonic()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % log_every == 0 or i == steps - 1:
            dt = (time.monotonic() - t0) / (i + 1)
            print(f"step {i:4d}  loss {loss:.4f}  ({dt*1e3:.0f} ms/step)")
    return losses


def train_via_pilots(arch: str, smoke: bool, steps: int, *, ckpt: str | None,
                     fail_at: float | None, n_pilots: int = 1,
                     seq: int = 64, batch: int = 2):
    repo = TaskRepo(lease_ttl=5.0)
    sim = ClusterSim(repo=repo)
    resume = {"ckpt_dir": ckpt, "ckpt_every": max(steps // 10, 1)} if ckpt else {}
    tid = repo.submit(
        PayloadImage(arch=arch, shape=f"custom:{seq}x{batch}", mode="train",
                     smoke=smoke),
        n_steps=steps, max_wall=3600.0, resume=resume)
    slices = sim.provision(n_pilots)
    pilots = [sim.spawn_pilot(s, PilotConfig(max_payloads=4, idle_grace=3.0))
              for s in slices]
    if fail_at is not None:
        time.sleep(fail_at)
        print(f"[train] injecting node failure on pilot {pilots[0].pilot_id}")
        sim.fail_node(slices[0].slice_id)
        # a replacement pilot takes over after the lease expires
        (s2,) = sim.provision(1)
        pilots.append(sim.spawn_pilot(s2, PilotConfig(max_payloads=4,
                                                      idle_grace=6.0)))
    ok = sim.run_until_drained(timeout=3600.0)
    sim.join_all(timeout=30.0)
    res = repo.result(tid)
    print(f"[train] drained={ok} repo={repo.stats()}")
    if res is not None:
        t = res.telemetry
        print(json.dumps({
            "task": tid, "pilot": res.pilot_id, "exit": res.exitcode,
            "steps": t.get("steps"), "resumed_from": t.get("resumed_from"),
            "first_loss": t.get("first_loss"), "last_loss": t.get("last_loss"),
        }, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--direct", action="store_true",
                    help="plain jit loop, no pilot system")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--fail-at", type=float, default=None,
                    help="seconds until a simulated node failure")
    ap.add_argument("--pilots", type=int, default=1)
    args = ap.parse_args()

    if args.direct:
        cfg = (get_smoke_config(args.arch) if args.smoke
               else get_config(args.arch))
        losses = train_direct(cfg, args.steps, args.batch, args.seq)
        print(f"[train] first={losses[0]:.4f} last={losses[-1]:.4f}")
    else:
        train_via_pilots(args.arch, args.smoke, args.steps,
                         ckpt=args.ckpt, fail_at=args.fail_at,
                         n_pilots=args.pilots, seq=args.seq,
                         batch=args.batch)


if __name__ == "__main__":
    main()
