"""input_specs(): ShapeDtypeStruct stand-ins for every step-function input.

The dry-run lowers ``step(*input_specs(...))`` — weak-type-correct, shardable,
zero device allocation.  Train steps take (state, batch); prefill takes
(params, batch); decode takes (params, decode_state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.api import build_model, init_decode_state
from repro.optim.adamw import init_opt_state


def param_specs(cfg: ArchConfig, *, dtype=None):
    bundle = build_model(cfg)
    specs = jax.eval_shape(lambda: bundle.init(jax.random.key(0)))
    if dtype is not None:
        specs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
            specs)
    return specs


def train_state_specs(cfg: ArchConfig):
    """{"params", "opt": {"m","v","step"}} as ShapeDtypeStructs (f32 master)."""
    ps = param_specs(cfg)
    opt = jax.eval_shape(functools.partial(init_opt_state), ps)
    return {"params": ps, "opt": opt}


def decode_state_specs(cfg: ArchConfig, shape: ShapeSpec, *, dtype=jnp.bfloat16):
    return jax.eval_shape(functools.partial(
        init_decode_state, cfg, shape.global_batch, shape.seq_len, dtype=dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, *, with_targets=True,
                compute=jnp.bfloat16):
    bundle = build_model(cfg)
    if with_targets:
        return bundle.train_batch_specs(shape, compute)
    return bundle.prefill_batch_specs(shape, compute)


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mode: str):
    """The lower() argument tuple for the given step kind."""
    if mode == "train":
        return (train_state_specs(cfg), batch_specs(cfg, shape))
    if mode == "prefill":
        return (param_specs(cfg, dtype=jnp.bfloat16),
                batch_specs(cfg, shape, with_targets=False))
    if mode == "decode":
        return (param_specs(cfg, dtype=jnp.bfloat16),
                decode_state_specs(cfg, shape))
    raise ValueError(f"unknown mode {mode!r}")
