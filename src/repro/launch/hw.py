"""Target-hardware constants (TPU v5e) used by the roofline analysis."""

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per ICI link (~50 GB/s/link)
HBM_BYTES = 16 * 2**30     # 16 GiB HBM per chip
