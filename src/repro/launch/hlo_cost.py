"""Trip-count-aware cost model over compiled HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts a while-loop (lax.scan) body
ONCE, not x trip-count — so every scanned-layer model under-reports FLOPs,
bytes, and (in a naive parse) collectives by the number of layer groups /
sequence chunks.  This module re-derives the three roofline inputs from the
post-SPMD HLO text with loop multipliers:

* Execution walk starts at ENTRY; a ``while`` multiplies its body's costs by
  the trip count (parsed from the loop-condition's comparison constant — jax
  scans always lower to a 0..N counter loop).
* ``fusion``/``call`` descend into the called computation (costs counted per
  call site, matching execution semantics).
* FLOPs: ``dot`` = 2 x numel(result) x prod(contracting dims); elementwise /
  reduce = numel(result); transcendentals count 1/element.
* Bytes, two conventions reported side by side:
    - ``bytes`` (unfused): operands + result per instruction — what XLA:CPU's
      own cost analysis would report, an upper bound;
    - ``bytes_fused`` (TPU fusion model): elementwise / broadcast / reshape /
      convert chains are assumed fused into their producers (ride in
      registers/VMEM); matmul IO, reductions' outputs, layout-changing ops
      (transpose/gather/scatter/concat), cache updates, and collectives
      count.  This is the §Roofline memory term.
  Special cases in both: dynamic-slice reads only the slice (result bytes),
  not the full xs; dynamic-update-slice touches 2 x update bytes, not the
  full buffer.  Bookkeeping ops (parameter/constant/tuple/get-tuple-element/
  bitcast) are free.
* Collectives: the byte conventions of ``hlo_stats`` (all-reduce 2x result,
  reduce-scatter operand, others result) x loop multiplier.

Everything is per-device (the HLO is one SPMD program), so term_seconds =
cost / per-chip peak directly.
"""

from __future__ import annotations

import dataclasses
import math
import re

from repro.launch.hlo_stats import COLLECTIVES, _DTYPE_BYTES

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "opt-barrier",
             "iota", "custom-call"}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "cosine", "sine", "logistic", "exponential-minus-one"}


def _type_info(type_str: str):
    """-> (bytes, numel) over all shapes in a (possibly tuple) type."""
    total_b, total_n = 0, 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.groups()
        sz = _DTYPE_BYTES.get(dt)
        if sz is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * sz
        total_n += n
    return total_b, total_n


def _split_type_op(rhs: str):
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                rest = rhs[i + 1:].strip()
                return rhs[: i + 1], rest
        return None
    parts = rhs.split(None, 1)
    if len(parts) != 2:
        return None
    return parts[0], parts[1]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rhs: str
    rest: str           # rhs after the type (opcode + operands + attrs)


def parse_module(hlo_text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    entry: str | None = None
    cur: list[Instr] | None = None
    for line in hlo_text.splitlines():
        h = _COMP_HDR.match(line.strip())
        if h:
            name = h.group(2)
            comps[name] = []
            cur = comps[name]
            if h.group(1):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        st = _split_type_op(rhs)
        if st is None:
            continue
        type_str, rest = st
        opcode = rest.split("(", 1)[0].strip()
        cur.append(Instr(name, type_str, opcode, rhs, rest))
    comps["__entry__"] = comps.get(entry, [])
    return comps


def _trip_count(comps, cond_name: str) -> int:
    """jax scans lower to `compare(i, constant(N)), direction=LT` loops."""
    best = 1
    for ins in comps.get(cond_name, []):
        for m in _CONST_INT.finditer(ins.rhs):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    _, out_n = _type_info(ins.type_str)
    ops = _OPERAND.findall(ins.rest.split(")", 1)[0])
    lhs_t = types.get(ops[0], "") if ops else ""
    cm = _CONTRACT.search(ins.rest)
    contract = 1
    if cm and lhs_t:
        dims_str = _SHAPE.search(lhs_t)
        if dims_str:
            shape = [int(d) for d in dims_str.group(2).split(",") if d]
            for ci in (int(x) for x in cm.group(1).split(",") if x):
                if ci < len(shape):
                    contract *= shape[ci]
    return 2.0 * out_n * contract


def _operand_bytes(ins: Instr, types: dict[str, str]) -> int:
    args = ins.rest.split("(", 1)[1] if "(" in ins.rest else ""
    total = 0
    for m in _OPERAND.finditer(args.split(")")[0]):
        t = types.get(m.group(1))
        if t is not None:
            total += _type_info(t)[0]
    return total


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0            # unfused: every op's operands+results
    bytes_fused: float = 0.0      # TPU-fusion model: see module docstring
    transcendentals: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: dict[str, float] = dataclasses.field(
        default_factory=dict)
    # (opcode, type_str, trips) -> total weighted bytes; top contributors
    collective_detail: dict[tuple, float] = dataclasses.field(
        default_factory=dict)
    # (opcode, type_str) -> total fused bytes (diagnostic breakdown)
    bytes_detail: dict[tuple, float] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def top_collectives(self, k: int = 12) -> list[tuple]:
        return sorted(self.collective_detail.items(),
                      key=lambda kv: -kv[1])[:k]

    def top_bytes(self, k: int = 12) -> list[tuple]:
        return sorted(self.bytes_detail.items(), key=lambda kv: -kv[1])[:k]


def module_cost(hlo_text: str, max_depth: int = 64) -> ModuleCost:
    comps = parse_module(hlo_text)
    cost = ModuleCost()

    def fused(ins, base, nbytes):
        cost.bytes_fused += nbytes
        key = (base, ins.type_str.split("{")[0])
        cost.bytes_detail[key] = cost.bytes_detail.get(key, 0.0) + nbytes

    def walk(comp_name: str, mult: float, depth: int):
        if depth > max_depth:
            return
        instrs = comps.get(comp_name, [])
        types = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            op = ins.opcode
            base = op.split(".")[0]
            # ---- control flow ------------------------------------------
            if base == "while":
                cond = _COND_ATTR.search(ins.rest)
                body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
                if body:
                    walk(body.group(1), mult * trips, depth + 1)
                continue
            if base == "conditional":
                bm = _BRANCHES.search(ins.rest)
                if bm:
                    for b in _OPERAND.findall(bm.group(1)):
                        walk(b, mult, depth + 1)
                continue
            if base in ("fusion", "call", "async-start"):
                cm = _CALL_ATTR.search(ins.rest)
                if cm:
                    walk(cm.group(1), mult, depth + 1)
                continue
            # ---- collectives --------------------------------------------
            hit = None
            for c in COLLECTIVES:
                if base == c or base == c + "-start":
                    hit = c
                    break
            if hit is not None:
                rb = _type_info(ins.type_str)[0]
                if base.endswith("-start"):
                    rb = rb / 2
                if hit == "all-reduce":
                    nb = 2.0 * rb
                elif hit == "reduce-scatter":
                    ob = _operand_bytes(ins, types)
                    nb = float(ob or rb)
                else:
                    nb = float(rb)
                cost.collective_bytes[hit] = (
                    cost.collective_bytes.get(hit, 0.0) + mult * nb)
                cost.collective_counts[hit] = (
                    cost.collective_counts.get(hit, 0.0) + mult)
                key = (hit, ins.type_str.split("{")[0], int(mult))
                cost.collective_detail[key] = (
                    cost.collective_detail.get(key, 0.0) + mult * nb)
                cost.bytes += mult * 2 * rb       # they also touch HBM
                fused(ins, hit, mult * 2 * rb)
                continue
            # ---- compute / data movement ---------------------------------
            if base in _FREE_OPS:
                continue
            rb, rn = _type_info(ins.type_str)
            if base == "dot":
                cost.flops += mult * _dot_flops(ins, types)
                io = _operand_bytes(ins, types) + rb
                cost.bytes += mult * io
                fused(ins, base, mult * io)       # matmul IO always real
            elif base == "convolution":
                # not used by these models; treat as elementwise fallback
                cost.flops += mult * rn
                io = _operand_bytes(ins, types) + rb
                cost.bytes += mult * io
                fused(ins, base, mult * io)
            elif base == "dynamic-slice":
                cost.bytes += mult * rb
                fused(ins, base, mult * rb)
            elif base == "dynamic-update-slice":
                args = ins.rest.split("(", 1)[1].split(")")[0]
                ops = _OPERAND.findall(args)
                upd = _type_info(types.get(ops[1], ""))[0] if len(ops) > 1 else rb
                cost.bytes += mult * 2 * upd
                fused(ins, base, mult * 2 * upd)
            elif base in ("broadcast", "reshape", "slice", "convert",
                          "reverse", "transpose", "copy"):
                # fuse away on TPU: elementwise-adjacent data movement and
                # layout transposes/copies are layout-assignment artifacts of
                # the CPU lowering (e.g. bf16 weights get convert+transpose+
                # copy'd to f32 before every CPU dot — TPU MXUs consume bf16
                # in place).  Counted in the unfused convention only.
                cost.bytes += mult * rb * (2 if base in ("transpose", "copy")
                                           else 1)
            elif base in ("concatenate", "pad", "gather", "scatter",
                          "select-and-scatter", "sort"):
                f = 2 if base in ("gather", "scatter", "sort") else 1
                cost.bytes += mult * rb * f
                fused(ins, base, mult * rb * f)    # these do materialize
            elif base == "reduce" or base == "reduce-window":
                cost.flops += mult * rn
                cost.bytes += mult * (_operand_bytes(ins, types) + rb)
                fused(ins, base, mult * rb)       # input fused into producer
            else:
                # elementwise / compare / select / rng / ...
                if base in _TRANSCENDENTAL:
                    cost.transcendentals += mult * rn
                cost.flops += mult * rn
                cost.bytes += mult * (_operand_bytes(ins, types) + rb)
                # fused model: elementwise chains ride in registers/VMEM
        return

    walk("__entry__", 1.0, 0)
    return cost
