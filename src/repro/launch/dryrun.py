import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]

Single-cell mode runs in-process; ``--all`` spawns one subprocess per cell
(fresh XLA state, bounded memory) and aggregates JSON records under
``results/dryrun/<mesh>/``.  The 512 placeholder host devices exist ONLY in
this entrypoint — nothing else in the repo sets XLA_FLAGS.
"""

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, applicable_shapes, get_config, list_archs
from repro.launch import hw
from repro.launch.hlo_cost import module_cost
from repro.launch.hlo_stats import collective_stats, cost_summary, memory_summary
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim.adamw import OptimConfig
from repro.runtime import sharding as shd

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _shardings_for(cfg, shape, mode, mesh, specs, moe_partition="tp",
                   layout="2d"):
    """(in_shardings, out_shardings, donate_argnums) for the step kind."""
    if mode == "train":
        state_sh = shd.train_state_shardings(specs[0]["params"], mesh,
                                             moe_partition=moe_partition,
                                             layout=layout)
        batch_sh = shd.batch_shardings(specs[1], mesh, layout)
        metrics_sh = NamedSharding(mesh, P())
        return (state_sh, batch_sh), (state_sh, metrics_sh), (0,)
    if mode == "prefill":
        param_sh = shd.param_shardings(specs[0], mesh, "serve",
                                       moe_partition=moe_partition,
                                       layout=layout)
        batch_sh = shd.batch_shardings(specs[1], mesh, layout)
        return (param_sh, batch_sh), None, ()
    # decode
    param_sh = shd.param_shardings(specs[0], mesh, "serve",
                                   moe_partition=moe_partition, layout=layout)
    state_sh = shd.decode_state_shardings(specs[1], mesh)
    return (param_sh, state_sh), (None, state_sh), (1,)


def _step_fn(cfg, mode, flags: dict):
    if mode == "train":
        return make_train_step(cfg, OptimConfig(total_steps=10_000))
    if mode == "prefill":
        return make_prefill_step(cfg)
    return make_serve_step(cfg)


def _model_flops(cfg, shape, mode) -> float:
    n = cfg.active_param_count()
    if mode == "train":
        return 6.0 * n * shape.tokens
    if mode == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: 1 new token/seq


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             flags: dict | None = None, save_hlo: bool = False,
             moe_partition: str = "tp", layout: str = "2d") -> dict:
    flags = flags or {}
    cfg = get_config(arch)
    if flags:
        cfg = dataclasses.replace(cfg, **flags)
    shape = SHAPES[shape_name]
    mode = shape.mode
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "mesh": {"shape": list(mesh.devices.shape),
                 "axes": list(mesh.axis_names)},
        "flags": flags, "moe_partition": moe_partition, "layout": layout,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }

    specs = input_specs(cfg, shape, mode)
    in_sh, out_sh, donate = _shardings_for(cfg, shape, mode, mesh, specs,
                                           moe_partition, layout)
    step = _step_fn(cfg, mode, flags)

    t0 = time.monotonic()
    with mesh, shd.activation_sharding(mesh, layout):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*specs)
        rec["lower_seconds"] = time.monotonic() - t0
        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_seconds"] = time.monotonic() - t1

    rec["memory"] = memory_summary(compiled)
    # XLA cost_analysis counts while-loop (scan) bodies ONCE — kept only as
    # a reference.  The roofline reads from the trip-count-aware HLO walk.
    rec["cost_analysis_raw"] = cost_summary(compiled)
    hlo = compiled.as_text()
    rec["collectives_raw"] = collective_stats(hlo)
    mc = module_cost(hlo)
    rec["hlo_cost"] = {
        "flops": mc.flops,
        "bytes_unfused": mc.bytes,
        "bytes_fused": mc.bytes_fused,
        "transcendentals": mc.transcendentals,
        "collective_bytes": mc.collective_bytes,
        "collective_counts": mc.collective_counts,
        "total_collective_bytes": mc.total_collective_bytes,
        "top_collectives": [
            {"op": k[0], "type": k[1], "trips": k[2], "bytes": v}
            for k, v in mc.top_collectives()],
    }
    if save_hlo:
        rec["hlo_path"] = str(RESULTS / "hlo" / f"{arch}__{shape_name}.txt")
        p = pathlib.Path(rec["hlo_path"])
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(hlo)

    # ---- roofline terms (seconds, per device == per the assignment's
    # global-bytes / (chips x bw) convention) -------------------------------
    flops_dev = mc.flops
    bytes_dev = mc.bytes_fused        # TPU-fusion convention (see hlo_cost)
    coll_dev = mc.total_collective_bytes
    terms = {
        "compute_s": flops_dev / hw.PEAK_FLOPS,
        "memory_s": bytes_dev / hw.HBM_BW,
        "collective_s": coll_dev / hw.ICI_BW,
    }
    terms["dominant"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    model_flops = _model_flops(cfg, shape, mode)
    terms["model_flops_global"] = model_flops
    terms["model_flops_per_chip"] = model_flops / n_chips
    terms["useful_flops_ratio"] = (
        model_flops / n_chips / flops_dev if flops_dev else None)
    bound_s = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_step_s"] = bound_s
    terms["roofline_fraction"] = (
        (model_flops / n_chips / hw.PEAK_FLOPS) / bound_s if bound_s else None)
    rec["roofline"] = terms

    # fits-in-HBM check
    mem = rec["memory"].get("total_nonalias_bytes")
    rec["fits_hbm"] = None if mem is None else bool(mem < hw.HBM_BYTES)
    return rec


# --------------------------------------------------------------------------
# serve-mesh accounting: per-SHARD memory / FLOPs for a mesh-bound serve
# image, without building the mesh (pure shape math + the sharding rules'
# divisor mirrors) — what the dry run previously got wrong by quoting
# whole-pool numbers for a sharded engine.
# --------------------------------------------------------------------------


def run_serve_cell(arch: str, *, mesh_shape: tuple = (1, 1),
                   slots: int = 4, max_len: int | None = None,
                   kv: str = "paged", num_blocks: int | None = None,
                   block_size: int = 16, smoke: bool = False) -> dict:
    """Roofline accounting for ONE serve engine on a ``(data, model)``
    mesh.  Everything is ``jax.eval_shape`` + the pure shard-factor
    mirrors of the serve sharding rules (`serve_param_shard_factor` /
    `serve_state_shard_factor`), so this runs in milliseconds on any
    host: per-device bytes divide each leaf by exactly the factor the
    real `serve_*_shardings` would apply (divisibility-gated, dtype
    aware), instead of pretending the whole pool lives on every chip."""
    from repro.configs.base import get_smoke_config
    from repro.models.api import build_model, init_decode_state

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    msz = int(mesh_shape[1])
    n_dev = int(mesh_shape[0]) * msz
    ml = max_len or 1024
    bundle = build_model(cfg)
    params = jax.eval_shape(bundle.init, jax.random.key(0))
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, slots, ml, kv=kv,
                                  num_blocks=num_blocks,
                                  block_size=block_size))

    def _account(tree, factor_fn):
        total = [0]
        per_dev = [0]
        def one(path, leaf):
            b = int(leaf.size) * leaf.dtype.itemsize
            total[0] += b
            per_dev[0] += b // factor_fn(path, leaf.shape, msz)
        jax.tree_util.tree_map_with_path(one, tree)
        return total[0], per_dev[0]

    p_total, p_dev = _account(params, shd.serve_param_shard_factor)
    s_total, s_dev = _account(state, shd.serve_state_shard_factor)
    kv_leaves = {"kp", "vp", "ckvp", "kropep", "k", "v", "ckv", "krope"}
    kv_total = [0]
    kv_dev = [0]
    def kv_one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if str(name) not in kv_leaves:
            return
        b = int(leaf.size) * leaf.dtype.itemsize
        kv_total[0] += b
        kv_dev[0] += b // shd.serve_state_shard_factor(path, leaf.shape, msz)
    jax.tree_util.tree_map_with_path(kv_one, state)

    # decode FLOPs: one token per slot per step.  The column-parallel
    # shards split the matmul work over the model axis; the data axis
    # replicates the engine's batch (one engine spans the whole mesh), so
    # per-device work divides by the MODEL size only.
    flops_global = 2.0 * cfg.active_param_count() * slots
    flops_dev = flops_global / msz
    mem_dev = p_dev + s_dev
    return {
        "arch": arch, "mode": "serve", "mesh_shape": list(mesh_shape),
        "mesh_devices": n_dev, "slots": slots, "max_len": ml, "kv": kv,
        "params_bytes": p_total, "params_bytes_per_device": p_dev,
        "state_bytes": s_total, "state_bytes_per_device": s_dev,
        "kv_pool_bytes": kv_total[0],
        "kv_pool_bytes_per_device": kv_dev[0],
        "bytes_per_device": mem_dev,
        "decode_flops": flops_global,
        "decode_flops_per_device": flops_dev,
        "decode_compute_s": flops_dev / hw.PEAK_FLOPS,
        "decode_memory_s": mem_dev / hw.HBM_BW,
        "fits_hbm_per_device": bool(mem_dev < hw.HBM_BYTES),
    }


# --------------------------------------------------------------------------


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for s in applicable_shapes(cfg):
            cells.append((arch, s))
    return cells


def _cell_path(arch, shape_name, multi_pod) -> pathlib.Path:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    return RESULTS / mesh_tag / f"{arch}__{shape_name}.json"


def run_all(multi_pod: bool, skip_existing: bool, timeout: float = 3000.0):
    cells = all_cells()
    print(f"[dryrun] {len(cells)} cells, multi_pod={multi_pod}")
    failures = []
    for i, (arch, shape_name) in enumerate(cells):
        out = _cell_path(arch, shape_name, multi_pod)
        if skip_existing and out.exists():
            print(f"[{i+1:2d}/{len(cells)}] {arch} x {shape_name}: cached")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name]
        if multi_pod:
            cmd.append("--multi-pod")
        t0 = time.monotonic()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=timeout)
            ok = r.returncode == 0 and out.exists()
        except subprocess.TimeoutExpired:
            r, ok = None, False
        dt = time.monotonic() - t0
        status = "ok" if ok else "FAIL"
        print(f"[{i+1:2d}/{len(cells)}] {arch} x {shape_name}: {status} "
              f"({dt:.0f}s)")
        if not ok:
            failures.append((arch, shape_name))
            if r is not None:
                tail = (r.stderr or r.stdout or "").strip().splitlines()[-12:]
                print("    " + "\n    ".join(tail))
    print(f"[dryrun] done; {len(failures)} failures: {failures}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--moe-partition", default="tp", choices=("tp", "ep"))
    ap.add_argument("--layout", default="2d", choices=("2d", "fsdp"))
    ap.add_argument("--flags", default="",
                    help='comma list key=value ArchConfig overrides, e.g. '
                         '"remat=dots,attn_impl=causal_blocked"')
    ap.add_argument("--serve-mesh", default=None,
                    help="per-shard serve accounting on a 'DxM' "
                         "(data, model) mesh — pure shape math, no "
                         "compile; e.g. '1x2'")
    ap.add_argument("--slots", type=int, default=4,
                    help="serve-mesh mode: engine slots")
    ap.add_argument("--serve-max-len", type=int, default=None,
                    help="serve-mesh mode: engine KV length")
    ap.add_argument("--smoke", action="store_true",
                    help="serve-mesh mode: smoke-sized config")
    args = ap.parse_args()

    if args.serve_mesh:
        d, m = args.serve_mesh.lower().split("x")
        rec = run_serve_cell(args.arch, mesh_shape=(int(d), int(m)),
                             slots=args.slots, max_len=args.serve_max_len,
                             smoke=args.smoke)
        print(json.dumps(rec, indent=1))
        return

    if args.all:
        fails = run_all(args.multi_pod, args.skip_existing)
        sys.exit(1 if fails else 0)

    flags = {}
    for kv in filter(None, args.flags.split(",")):
        k, v = kv.split("=")
        flags[k] = int(v) if v.lstrip("-").isdigit() else v

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   flags=flags, save_hlo=args.save_hlo,
                   moe_partition=args.moe_partition, layout=args.layout)
    out = _cell_path(args.arch, args.shape, args.multi_pod)
    if flags or args.moe_partition != "tp" or args.layout != "2d":
        tag = ",".join(f"{k}={v}" for k, v in sorted(flags.items()))
        if args.moe_partition != "tp":
            tag += ("," if tag else "") + f"moe={args.moe_partition}"
        if args.layout != "2d":
            tag += ("," if tag else "") + f"layout={args.layout}"
        out = out.with_name(out.stem + f"__{tag}" + ".json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(json.dumps({
        "cell": f"{args.arch} x {args.shape}",
        "mesh": rec["mesh"]["shape"],
        "compile_s": round(rec["compile_seconds"], 1),
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "dominant": r["dominant"],
        "useful_flops_ratio": r["useful_flops_ratio"],
        "roofline_fraction": r["roofline_fraction"],
        "mem_per_dev_GB": (rec["memory"].get("total_nonalias_bytes", 0) or 0) / 2**30,
        "fits_hbm": rec["fits_hbm"],
    }, indent=1))


if __name__ == "__main__":
    main()
