"""Production mesh construction.

``make_production_mesh()`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and nothing here may run before that.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
cross-pod (DCN/slower-ICI) axis and carries only batch-parallel traffic.
"""

from __future__ import annotations

import jax

from repro.runtime.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod else (DATA_AXIS, MODEL_AXIS)
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the test environment has."""
    return jax.make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))
