"""Batched-serving driver THROUGH the pilot system.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 16 --slots 4 [--wave] [--via-pilots] \
      [--pilots N [--fail-at K]]

Default runs the continuous-batching engine directly on a staggered-arrival
trace (``--wave`` selects the static wave-batching baseline for comparison);
``--via-pilots`` submits full inference servers as ``serve`` payloads: each
engine run — trace and all — is late-bound onto a pilot-held slice, and a
second model is served by the SAME pilot right after (the multi-payload
demo).  The first task carries a prefetch hint for the second image, so its
compile overlaps the first server's run.

``--pilots N`` runs the FLEET serve demo: the trace is split into
per-request leases in a FleetDispatcher pool and N pilots each run a server
that pulls from it.  ``--fail-at K`` hard-kills a lease-holding pilot once K
requests have completed — its in-flight requests requeue onto the survivors
and the trace still reaches 100% completion.

``--autoscale`` replays the trace as a bursty square-wave arrival schedule
under the demand-driven autoscaler (``core/autoscaler.py``): the fleet
grows from queue pressure, shrinks to zero in the gaps, and re-provisions
on the next burst.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.models.api import build_model
from repro.serving.dispatch import FleetDispatcher
from repro.serving.engine import ServeEngine


def make_trace(vocab_size: int, n_requests: int, *, max_len: int = 128,
               stagger: int = 1, seed: int = 0,
               dup_rate: float = 0.0) -> list[dict]:
    """Staggered-arrival request trace (the startup-spec format): request i
    becomes visible at engine tick ``i * stagger``, with mixed prompt
    lengths and token budgets.  ``dup_rate`` is the fraction of requests
    that repeat an earlier prompt verbatim (the repeated-query pattern the
    paged engine's prefix cache serves copy-free)."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        if trace and rng.random() < dup_rate:
            prompt = list(trace[int(rng.integers(0, len(trace)))]["prompt"])
        else:
            plen = int(rng.integers(4, max(5, max_len // 4)))
            prompt = rng.integers(0, vocab_size, size=plen).tolist()
        trace.append({
            "rid": i,
            "prompt": prompt,
            "max_new_tokens": int(rng.choice([6, 10, 18, 28])),
            "at_step": i * stagger,
        })
    return trace


def serve_direct(cfg, n_requests: int, slots: int, max_len: int,
                 seed: int = 0, admission: str = "continuous",
                 kv: str | None = None, prefill: str = "oneshot",
                 num_blocks: int | None = None,
                 dup_rate: float = 0.0, spec: str = "off", spec_k: int = 4,
                 draft_cfg=None, mesh_shape=None) -> dict:
    mesh = None
    if mesh_shape is not None:
        from repro.runtime.mesh import serve_mesh
        mesh = serve_mesh(mesh_shape)
    params = build_model(cfg).init(jax.random.key(seed))
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                      admission=admission, kv=kv, prefill=prefill,
                      num_blocks=num_blocks, spec=spec, spec_k=spec_k,
                      draft_cfg=draft_cfg, mesh=mesh)
    trace = make_trace(cfg.vocab_size, n_requests, max_len=max_len,
                       seed=seed, dup_rate=dup_rate)
    return eng.run_trace(trace)


def serve_via_pilots(archs: list[str], n_requests: int = 8,
                     n_steps: int = 400, slots: int | None = None,
                     max_len: int | None = None) -> None:
    """Several inference servers (different models!) multiplexed over ONE
    pilot — container late-binding for serving.  Task i hints task i+1's
    image so the pilot prefetches the next compile during the current run."""
    sim = ClusterSim()
    images = [PayloadImage(arch=a, shape="smoke", mode="serve") for a in archs]
    tids = []
    for i, (a, img) in enumerate(zip(archs, images)):
        cfg = get_smoke_config(a)
        # None = the image's factory geometry (shape spec) — which is also
        # what a prefetch warm() stages, so the default demo hits the
        # prefetched compile; explicit flags override both
        eff_max_len = max_len or img.shape_spec().seq_len
        trace = make_trace(cfg.vocab_size, n_requests, max_len=eff_max_len,
                           seed=i)
        hint = images[i + 1] if i + 1 < len(images) else None
        tids.append(sim.repo.submit(
            img, n_steps=n_steps, prefetch_hint=hint,
            payload_spec={"trace": trace, "max_len": max_len,
                          "slots": slots}))
    (s,) = sim.provision(1)
    pilot = sim.spawn_pilot(s, PilotConfig(max_payloads=len(archs) + 1,
                                           idle_grace=2.0))
    ok = sim.run_until_drained(timeout=600.0)
    sim.join_all(timeout=30.0)
    print(f"[serve] drained={ok} repo={sim.repo.stats()} "
          f"registry={sim.registry.stats}")
    for i, (tid, arch) in enumerate(zip(tids, archs)):
        r = sim.repo.result(tid)
        if r:
            sv = r.telemetry.get("serve", {})
            print(f"  {arch}: completed={sv.get('completed')} "
                  f"util={sv.get('slot_utilization', 0):.2f} "
                  f"tok/s={sv.get('tok_per_s', 0):.1f} "
                  f"ttft_p50={sv.get('ttft_p50_s')} "
                  f"(bind cached={pilot.history[i].get('bind_cached')})")


def serve_fleet(arch: str, n_requests: int, n_pilots: int, *,
                slots: int = 2, max_len: int = 64, fail_at: int | None = None,
                fail_count: int = 1, lease_ttl: float = 0.5,
                registry=None, seed: int = 0, draft: str | None = None,
                spec_k: int = 4, robustness=None, chaos_plan=None,
                poison: int = 0, mesh_shape=None,
                trace: list[dict] | None = None) -> dict:
    """The fleet serve demo/driver: N pilots lease requests from one pool.

    ``fail_at`` hard-kills ``fail_count`` lease-holding pilots (one at
    ``fail_at`` settled requests, the next one ``fail_at`` later, ...) —
    the requeue-on-pilot-failure path.  ``draft`` turns on speculative
    decoding on every server: a draft arch name, or ``"self"`` for the
    self-draft ablation (the image's fixed draft seed keeps requeued
    requests replaying bitwise on survivors).

    Chaos drills: ``robustness`` (a
    :class:`~repro.serving.dispatch.RobustnessPolicy`) turns on the
    dispatcher's gray-failure hardening; ``chaos_plan`` (a
    :class:`~repro.core.chaos.FaultPlan`) runs a
    :class:`~repro.core.chaos.ChaosController` against the fleet for the
    duration of the trace; ``poison`` appends that many poison request
    entries (lethal while the plan arms them — each kills the pilot that
    fetches it until the pool quarantines it).

    Returns pool + timing stats; the caller owns no threads when this
    returns (fleet drained, pool closed).
    """
    from repro.core.chaos import ChaosController

    cfg = get_smoke_config(arch)
    sim = ClusterSim(registry=registry)
    pool = FleetDispatcher(lease_ttl=lease_ttl, policy=robustness)
    if trace is None:
        trace = make_trace(cfg.vocab_size, n_requests, max_len=max_len,
                           seed=seed)
    else:
        trace = list(trace)
    poison_rids = list(range(n_requests, n_requests + poison))
    for rid in poison_rids:
        trace.append({"rid": rid, "prompt": [1, 2, 3, 4],
                      "max_new_tokens": 4, "poison": True})
    fleet = sim.spawn_fleet(n_pilots, PilotConfig(max_payloads=2,
                                                  idle_grace=0.3))
    img = PayloadImage(arch=arch, shape="smoke", mode="serve",
                       draft=None if draft in (None, "self") else draft,
                       mesh_shape=(tuple(mesh_shape)
                                   if mesh_shape is not None else None))
    server_spec = {"slots": slots, "max_len": max_len}
    if mesh_shape is not None:
        # the fleet path plumbs the mesh through the startup spec too, so
        # telemetry/debug dumps of the spec show what geometry was served
        server_spec["mesh_shape"] = list(tuple(mesh_shape))
    if draft is not None:
        server_spec.update({"spec": "draft", "spec_k": spec_k})
    tids = fleet.submit_servers(img, pool.name, n=n_pilots,
                                spec=server_spec)
    # submit traffic only once the fleet is up and WARM, so TTFT measures
    # serving (queue wait + requeue delay), not server cold start
    if not pool.wait_servers(n_pilots, timeout=300.0):
        pool.close()
        fleet.drain_all()
        fleet.join_all(30.0)
        raise RuntimeError(
            f"only {len(pool.servers)}/{n_pilots} servers came up within "
            f"300s — refusing to serve traffic into a half-started fleet")
    ctl = (ChaosController(sim, fleet, pool=pool, plan=chaos_plan)
           if chaos_plan is not None else None)
    t0 = time.monotonic()
    if ctl is not None:
        ctl.start()            # t=0 for the plan's fault offsets
    pool.submit_trace(trace)
    pool.seal()                # the demo trace is the whole workload
    failed_pilots: list[str] = []
    try:
        for k in range(fail_count if fail_at else 0):
            if not pool.wait_completed(fail_at * (k + 1), timeout=300.0):
                break
            victim = _pick_victim(fleet, pool, exclude=failed_pilots)
            if victim is None:
                break
            failed_pilots.append(victim.pilot_id)
            sim.fail_node(victim.slice.slice_id)
        ok = pool.wait_all(timeout=600.0)
    finally:
        if ctl is not None:
            ctl.stop()
        pool.close()
        fleet.drain_all()
        fleet.join_all(30.0)
    wall = time.monotonic() - t0
    fleet.reap()
    stats = pool.stats()
    recs = pool.records()
    ttfts = [r.first_token_s for r in recs.values()
             if r.first_token_s is not None]
    goodput = sum(len(r.tokens) for r in recs.values()
                  if r.tokens is not None) / wall if wall else 0.0
    # same percentile definition as ServeEngine._stats, so fleet and
    # single-engine ttft_p*_s rows are directly comparable
    pct = lambda v, q: float(np.percentile(v, q)) if v else None
    # speculative effectiveness, averaged over the servers that ran with
    # spec on (their serve telemetry survives in the repo's task results)
    spec_rows = []
    for tid in tids:
        r = sim.repo.result(tid)
        if r and r.telemetry.get("serve", {}).get("spec") == "draft":
            spec_rows.append(r.telemetry["serve"])
    mean = lambda k: (sum(s[k] for s in spec_rows) / len(spec_rows)
                      if spec_rows else 0.0)
    # block-pool leak audit: every server that exited gracefully reports
    # its engine's residual allocation (killed servers can't — their KV
    # state died with the simulated node, which leaks nothing real)
    leaked = sum(r.telemetry["serve"]["fleet"].get("leaked_blocks", 0)
                 for r in (sim.repo.result(t) for t in tids)
                 if r and r.telemetry.get("serve", {}).get("fleet"))
    return {
        "drained": ok,
        "wall_s": wall,
        "goodput_tok_per_s": goodput,
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p99_s": pct(ttfts, 99),
        "failed_pilots": failed_pilots,
        "pilot_seconds": fleet.pilot_seconds(),
        "results": pool.results(),
        "spec_servers": len(spec_rows),
        "acceptance_rate": mean("acceptance_rate"),
        "tokens_per_step": mean("tokens_per_step"),
        "leaked_blocks": leaked,
        "poison_rids": poison_rids,
        "quarantined_rids": sorted(r.rid for r in recs.values()
                                   if r.quarantined),
        "fail_reasons": {r.rid: r.fail_reason for r in recs.values()
                         if r.failed},
        "chaos": ctl.stats() if ctl is not None else None,
        **stats,
    }


def serve_disagg(arch: str, n_requests: int, *, prefill_pilots: int = 2,
                 decode_pilots: int = 2, slots: int = 2, max_len: int = 64,
                 fail_prefill_at: int | None = None,
                 fail_decode_at: int | None = None, lease_ttl: float = 0.5,
                 registry=None, seed: int = 0,
                 trace: list[dict] | None = None) -> dict:
    """DISAGGREGATED fleet serve: prompts lease into a prefill pool whose
    engines export KV block handoffs; completed prefills become decode-pool
    leases (the :class:`~repro.serving.dispatch.DisaggRouter` forward) and
    a separate decode fleet resumes each stream from its handoff.

    ``fail_prefill_at`` / ``fail_decode_at`` hard-kill a lease-holding
    pilot of the respective stage after K settled requests in that stage —
    a dead prefill pilot's prompts replay from the PROMPT on survivors; a
    dead decode pilot's streams replay from the HANDOFF (the prompt is
    never re-prefilled).  Params come from the image seed on every server,
    so either replay reproduces the lost tokens bitwise.
    """
    from repro.serving.dispatch import DisaggRouter

    cfg = get_smoke_config(arch)
    sim = ClusterSim(registry=registry)
    router = DisaggRouter(lease_ttl=lease_ttl)
    if trace is None:
        trace = make_trace(cfg.vocab_size, n_requests, max_len=max_len,
                           seed=seed)
    pf_fleet = sim.spawn_fleet(prefill_pilots,
                               PilotConfig(max_payloads=2, idle_grace=0.3))
    dc_fleet = sim.spawn_fleet(decode_pilots,
                               PilotConfig(max_payloads=2, idle_grace=0.3))
    # role is part of the image key: the prefill image never compiles the
    # decode step; the decode image never compiles the admission prefills
    pf_img = PayloadImage(arch=arch, shape="smoke", mode="serve",
                          role="prefill")
    dc_img = PayloadImage(arch=arch, shape="smoke", mode="serve",
                          role="decode")
    pf_spec = {"slots": slots, "max_len": max_len,
               "server_labels": {"pool": "prefill"}}
    dc_spec = {"slots": slots, "max_len": max_len,
               "server_labels": {"pool": "decode"}}
    pf_tids = pf_fleet.submit_servers(pf_img, router.prefill.name,
                                      n=prefill_pilots, spec=pf_spec)
    dc_tids = dc_fleet.submit_servers(dc_img, router.decode.name,
                                      n=decode_pilots, spec=dc_spec)
    for pool, n in ((router.prefill, prefill_pilots),
                    (router.decode, decode_pilots)):
        if not pool.wait_servers(n, timeout=300.0):
            router.close()
            for f in (pf_fleet, dc_fleet):
                f.drain_all()
                f.join_all(30.0)
            raise RuntimeError(
                f"only {len(pool.servers)}/{n} {pool.name} servers came "
                f"up within 300s")
    t0 = time.monotonic()
    router.submit_trace(trace)
    router.seal()
    failed = {"prefill": [], "decode": []}
    try:
        for stage, pool, fleet, at in (
                ("prefill", router.prefill, pf_fleet, fail_prefill_at),
                ("decode", router.decode, dc_fleet, fail_decode_at)):
            if at is None:
                continue
            if not pool.wait_completed(at, timeout=300.0):
                continue
            victim = _pick_victim(fleet, pool)
            if victim is not None:
                failed[stage].append(victim.pilot_id)
                sim.fail_node(victim.slice.slice_id)
        ok = router.wait_all(timeout=600.0)
    finally:
        router.close()
        for f in (pf_fleet, dc_fleet):
            f.drain_all()
            f.join_all(30.0)
    wall = time.monotonic() - t0
    pf_fleet.reap()
    dc_fleet.reap()
    # end-to-end TTFT: the FIRST generated token exists at prefill export
    # (it rides the handoff), so the prefill-stage records — whose
    # first_token_s is measured against the ORIGINAL submit time — are the
    # honest time-to-first-token.  The decode-stage records measure the
    # same zero but include the decode pool's import queue: that is the
    # resume latency (time until the stream starts advancing again).
    recs = router.decode.records()
    ttfts = [r.first_token_s for r in router.prefill.records().values()
             if r.first_token_s is not None]
    resumes = [r.first_token_s for r in recs.values()
               if r.first_token_s is not None]
    pct = lambda v, q: float(np.percentile(v, q)) if v else None
    goodput = sum(len(r.tokens) for r in recs.values()
                  if r.tokens is not None) / wall if wall else 0.0
    leaked = exported = imported = 0
    for tid in pf_tids + dc_tids:
        r = sim.repo.result(tid)
        sv = r.telemetry.get("serve", {}) if r else {}
        if sv.get("fleet"):
            leaked += sv["fleet"].get("leaked_blocks", 0)
        exported += sv.get("prefills_exported", 0) or 0
        imported += sv.get("handoffs_imported", 0) or 0
    return {
        "drained": ok,
        "wall_s": wall,
        "goodput_tok_per_s": goodput,
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p99_s": pct(ttfts, 99),
        "resume_p50_s": pct(resumes, 50),
        "resume_p99_s": pct(resumes, 99),
        "failed_pilots": failed,
        "pilot_seconds": (pf_fleet.pilot_seconds()
                          + dc_fleet.pilot_seconds()),
        "results": router.results(),
        "leaked_blocks": leaked,
        "prefills_exported": exported,
        "handoffs_imported": imported,
        "pool_pressure": router.pool_pressure(),
        "stats": router.stats(),
    }


def serve_disagg_schedule(arch: str, schedule: list[tuple[float, dict]], *,
                          slots: int = 2, max_len: int = 64,
                          prefill_policy=None, decode_policy=None,
                          initial_pilots: int = 1, lease_ttl: float = 0.5,
                          idle_grace: float = 0.5, registry=None) -> dict:
    """Disaggregated fleets under TWO independent autoscalers, one per
    role pool, each reading its own label's ``pool_pressure()`` slice —
    the demand-shaped heterogeneous-pool loop: a prefill-bound trace grows
    only the prefill fleet, a decode-bound trace only the decode fleet."""
    from repro.core.autoscaler import FleetAutoscaler
    from repro.serving.dispatch import DisaggRouter

    sim = ClusterSim(registry=registry)
    router = DisaggRouter(lease_ttl=lease_ttl)
    pf_img = PayloadImage(arch=arch, shape="smoke", mode="serve",
                          role="prefill")
    dc_img = PayloadImage(arch=arch, shape="smoke", mode="serve",
                          role="decode")
    pf_spec = {"slots": slots, "max_len": max_len,
               "server_labels": {"pool": "prefill"}}
    dc_spec = {"slots": slots, "max_len": max_len,
               "server_labels": {"pool": "decode"}}
    pf_fleet = sim.spawn_fleet(initial_pilots,
                               PilotConfig(max_payloads=4,
                                           idle_grace=idle_grace))
    dc_fleet = sim.spawn_fleet(initial_pilots,
                               PilotConfig(max_payloads=4,
                                           idle_grace=idle_grace))
    scalers = []
    out: dict = {}
    try:
        if initial_pilots:
            pf_fleet.submit_servers(pf_img, router.prefill.name,
                                    n=initial_pilots, spec=pf_spec)
            dc_fleet.submit_servers(dc_img, router.decode.name,
                                    n=initial_pilots, spec=dc_spec)
            for pool in (router.prefill, router.decode):
                if not pool.wait_servers(initial_pilots, timeout=300.0):
                    raise RuntimeError(f"{pool.name} servers not warm "
                                       f"within 300s")
        for fleet, img, pool, label, policy, spec in (
                (pf_fleet, pf_img, router.prefill, "prefill",
                 prefill_policy, pf_spec),
                (dc_fleet, dc_img, router.decode, "decode",
                 decode_policy, dc_spec)):
            if policy is None:
                continue
            sc = FleetAutoscaler(fleet, img, pool=pool, pool_label=label,
                                 policy=policy, spec=spec)
            sc.start()
            scalers.append((label, sc))
        t0 = time.monotonic()
        for dt, entry in schedule:
            lag = dt - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            router.submit(entry)
        router.seal()
        out["drained"] = router.wait_all(timeout=600.0)
        out["wall_s"] = time.monotonic() - t0
    finally:
        for _, sc in scalers:
            sc.stop()
        router.close()
        for f in (pf_fleet, dc_fleet):
            f.drain_all()
            f.join_all(30.0)
            f.reap()
    recs = router.decode.records()
    ttfts = [r.first_token_s for r in recs.values()
             if r.first_token_s is not None]
    pct = lambda v, q: float(np.percentile(v, q)) if v else None
    out.update({
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p99_s": pct(ttfts, 99),
        "pilot_seconds": {"prefill": pf_fleet.pilot_seconds(),
                          "decode": dc_fleet.pilot_seconds()},
        "peak_pilots": {"prefill": None, "decode": None},
        "results": router.results(),
        "stats": router.stats(),
    })
    for label, sc in scalers:
        out.setdefault("autoscale", {})[label] = sc.stats()
        out["peak_pilots"][label] = sc.peak_live
    return out


def make_bursty_schedule(trace: list[dict], *, bursts: int, burst_s: float,
                         gap_s: float, seed: int = 0) -> list[tuple[float, dict]]:
    """Square-wave arrival schedule with Poisson arrivals inside each high
    phase: the trace is split evenly across ``bursts`` bursts; within a
    burst, inter-arrival gaps are exponential (rate = burst size /
    burst_s, clipped to the burst window), and between bursts the pool
    goes quiet for ``gap_s`` — the demand shape an autoscaler must track
    without flapping."""
    rng = np.random.default_rng(seed)
    per = (len(trace) + bursts - 1) // bursts
    out: list[tuple[float, dict]] = []
    for b in range(bursts):
        chunk = trace[b * per:(b + 1) * per]
        if not chunk:
            break
        t = b * (burst_s + gap_s)
        rate = len(chunk) / burst_s
        offs = np.minimum(np.cumsum(rng.exponential(1.0 / rate,
                                                    size=len(chunk))),
                          burst_s)
        for off, e in zip(offs, chunk):
            out.append((t + float(off), e))
    return out


def serve_fleet_schedule(arch: str, schedule: list[tuple[float, dict]], *,
                         slots: int = 2, max_len: int = 64,
                         policy=None, n_pilots: int | None = None,
                         initial_pilots: int = 1, lease_ttl: float = 0.5,
                         idle_grace: float = 0.5, registry=None,
                         settle_to_zero: bool = True) -> dict:
    """Drive a serving fleet through a WALL-CLOCK arrival schedule
    (``[(t_offset_s, entry), ...]``, sorted by offset).

    ``policy`` (an :class:`~repro.core.autoscaler.AutoscalePolicy`) runs
    the fleet under the demand-driven autoscaler starting from
    ``initial_pilots``; ``policy=None`` runs a STATIC fleet of
    ``n_pilots`` — the peak-sized baseline the autoscaler is judged
    against.  Returns pool stats + pool-level TTFT percentiles +
    ``pilot_seconds`` (fleet-lifetime slice holding, the cost metric) and,
    when autoscaled, the decision ledger / flap count / scale-to-zero
    outcome."""
    from repro.core.autoscaler import FleetAutoscaler

    sim = ClusterSim(registry=registry)
    pool = FleetDispatcher(lease_ttl=lease_ttl)
    img = PayloadImage(arch=arch, shape="smoke", mode="serve")
    spec = {"slots": slots, "max_len": max_len}
    n_start = n_pilots if policy is None else max(policy.min_pilots,
                                                 initial_pilots)
    if policy is None and n_pilots is None:
        raise ValueError("static mode needs n_pilots")
    fleet = sim.spawn_fleet(n_start, PilotConfig(max_payloads=4,
                                                 idle_grace=idle_grace))
    scaler = None
    out: dict = {}
    try:
        if n_start:
            fleet.submit_servers(img, pool.name, n=n_start, spec=spec)
            if not pool.wait_servers(n_start, timeout=300.0):
                raise RuntimeError(
                    f"only {len(pool.servers)}/{n_start} servers warm "
                    f"within 300s")
        if policy is not None:
            scaler = FleetAutoscaler(fleet, img, pool=pool, policy=policy,
                                     spec=spec)
            scaler.start()
        t0 = time.monotonic()
        for dt, entry in schedule:
            lag = dt - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            pool.submit(entry)
        pool.seal()
        ok = pool.wait_all(timeout=600.0)
        wall = time.monotonic() - t0
        out["drained"] = ok
        out["wall_s"] = wall
        if scaler is not None and policy.min_pilots == 0 and settle_to_zero:
            # the empty-trace epilogue: demand is 0, so the loop must shed
            # every pilot (victims exit via drain/idle_grace) — the
            # scale-to-zero half of the (g)->(h) lifecycle
            budget = (policy.down_cooldown
                      + policy.down_stable_ticks * policy.interval + 30.0)
            deadline = time.monotonic() + budget
            while fleet.size() > 0 and time.monotonic() < deadline:
                time.sleep(0.05)
            out["scaled_to_zero"] = fleet.size() == 0
            out["scale_to_zero_s"] = time.monotonic() - t0 - wall
    finally:
        if scaler is not None:
            scaler.stop()
        pool.close()
        fleet.drain_all()
        fleet.join_all(30.0)
        fleet.reap()
    recs = pool.records()
    ttfts = [r.first_token_s for r in recs.values()
             if r.first_token_s is not None]
    pct = lambda v, q: float(np.percentile(v, q)) if v else None
    out.update({
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p99_s": pct(ttfts, 99),
        "pilot_seconds": fleet.pilot_seconds(),
        "results": pool.results(),
        **pool.stats(),
    })
    if scaler is not None:
        out["autoscale"] = scaler.stats()
        out["decisions"] = [dataclasses.asdict(d) for d in scaler.decisions]
        out["t_start"] = t0
    return out


def _pick_victim(fleet, pool, *, exclude=()):
    """The live pilot holding the most request leases (never a survivor of
    a previous kill round that holds none — killing an idle pilot exercises
    nothing)."""
    holders = pool.lease_holders()
    best, best_n = None, -1
    for p in fleet.live():
        if p.pilot_id in exclude:
            continue
        n = len(holders.get(p.pilot_id, []))
        if n > best_n:
            best, best_n = p, n
    return best if best_n > 0 else None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--archs", default=None,
                    help="comma list for --via-pilots multi-model demo")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=None,
                    help="engine slots (default: 4 direct; image shape "
                         "via pilots)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="engine KV length (default: 128 direct; image "
                         "shape via pilots)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--wave", action="store_true",
                    help="static wave-batching baseline (for comparison)")
    ap.add_argument("--kv", choices=("paged", "dense"), default=None,
                    help="KV layout (default: paged for decoder LMs; "
                         "dense is the ablation)")
    ap.add_argument("--prefill", choices=("oneshot", "chunked"),
                    default="oneshot",
                    help="admission prefill: whole-bucket, or chunks "
                         "interleaved with decode")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged pool size (default: dense-equivalent)")
    ap.add_argument("--dup-rate", type=float, default=0.0,
                    help="fraction of repeated prompts (prefix-cache hits)")
    ap.add_argument("--draft", default=None,
                    help="speculative decoding: draft model arch, or "
                         "'self' for the self-draft ablation (direct and "
                         "fleet modes)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative step")
    ap.add_argument("--mesh", default=None,
                    help="serve over a device mesh, 'AxB' = (data, model) "
                         "— e.g. '1x2' shards params + paged KV pools on "
                         "the head axis over 2 devices (direct and fleet "
                         "modes)")
    ap.add_argument("--via-pilots", action="store_true")
    ap.add_argument("--pilots", type=int, default=None,
                    help="fleet serve: N pilots lease requests from one "
                         "shared pool")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="fleet serve: hard-kill a lease-holding pilot "
                         "after K completed requests")
    ap.add_argument("--chaos", action="store_true",
                    help="fleet serve: run the canned chaos drill (crash + "
                         "stall + slow + flaky heartbeat + one poison "
                         "request) with gray-failure hardening on")
    ap.add_argument("--hedge", type=float, default=None,
                    help="fleet serve: enable hedged re-dispatch with this "
                         "straggler budget factor (x pool p95 service time)")
    ap.add_argument("--quarantine-after", type=int, default=None,
                    help="fleet serve: quarantine a request once this many "
                         "distinct pilots died holding it (0 disables)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serve: a prefill fleet exports KV "
                         "handoffs that a decode fleet resumes (pool sizes "
                         "via --prefill-pilots/--decode-pilots)")
    ap.add_argument("--prefill-pilots", type=int, default=2,
                    help="disagg: prefill pool size")
    ap.add_argument("--decode-pilots", type=int, default=2,
                    help="disagg: decode pool size")
    ap.add_argument("--fail-prefill-at", type=int, default=None,
                    help="disagg: kill a prefill pilot after K settled "
                         "prefills (replay-from-prompt)")
    ap.add_argument("--fail-decode-at", type=int, default=None,
                    help="disagg: kill a decode pilot after K finished "
                         "streams (replay-from-handoff)")
    ap.add_argument("--autoscale", action="store_true",
                    help="fleet serve on a bursty square-wave trace with "
                         "the demand-driven autoscaler (--pilots caps the "
                         "fleet; starts at 1, scales to zero in the gaps)")
    args = ap.parse_args()

    mesh_shape = None
    if args.mesh:
        from repro.runtime.mesh import parse_mesh_shape
        mesh_shape = parse_mesh_shape(args.mesh)

    if args.disagg:
        out = serve_disagg(args.arch, args.requests,
                           prefill_pilots=args.prefill_pilots,
                           decode_pilots=args.decode_pilots,
                           slots=args.slots or 2,
                           max_len=args.max_len or 64,
                           fail_prefill_at=args.fail_prefill_at,
                           fail_decode_at=args.fail_decode_at)
        out.pop("results")
        out.pop("pool_pressure", None)
        print(json.dumps(out, indent=1))
        return
    if args.autoscale:
        from repro.core.autoscaler import AutoscalePolicy
        cfg = get_smoke_config(args.arch)
        max_len = args.max_len or 64
        slots = args.slots or 2
        n_peak = args.pilots or 4
        trace = make_trace(cfg.vocab_size, args.requests, max_len=max_len)
        schedule = make_bursty_schedule(trace, bursts=3, burst_s=1.0,
                                        gap_s=5.0)
        out = serve_fleet_schedule(
            args.arch, schedule, slots=slots, max_len=max_len,
            policy=AutoscalePolicy(min_pilots=0, max_pilots=n_peak,
                                   slots_per_pilot=slots))
        out.pop("results")
        out.pop("t_start", None)
        print(json.dumps(out, indent=1))
        return
    if args.pilots:
        robustness, chaos_plan, poison = None, None, 0
        if args.chaos or args.hedge is not None \
                or args.quarantine_after is not None:
            from repro.serving.dispatch import RobustnessPolicy
            robustness = RobustnessPolicy()
            if args.hedge is not None:
                robustness.hedge_factor = args.hedge
            if args.quarantine_after is not None:
                robustness.quarantine_after = args.quarantine_after
        if args.chaos:
            from repro.core.chaos import FaultPlan, FaultSpec
            chaos_plan = FaultPlan(faults=[
                FaultSpec(kind="crash", at_s=0.5),
                FaultSpec(kind="stall", at_s=1.0, duration_s=2.0),
                FaultSpec(kind="slow", at_s=1.5, duration_s=2.0, factor=5.0),
                FaultSpec(kind="flaky_heartbeat", at_s=1.5, duration_s=2.0),
            ], poison=True)
            poison = 1
        out = serve_fleet(args.arch, args.requests, args.pilots,
                          slots=args.slots or 2, max_len=args.max_len or 64,
                          fail_at=args.fail_at, draft=args.draft,
                          spec_k=args.spec_k, robustness=robustness,
                          chaos_plan=chaos_plan, poison=poison,
                          mesh_shape=mesh_shape)
        out.pop("results")
        if mesh_shape is not None:
            print(f"[mesh] shape={'x'.join(map(str, mesh_shape))} "
                  f"(fleet: every server shards over its own mesh)")
        if args.draft:
            print(f"[spec] servers={out['spec_servers']} "
                  f"acceptance_rate={out['acceptance_rate']:.3f} "
                  f"tokens_per_step={out['tokens_per_step']:.2f}")
        print(json.dumps(out, indent=1))
        return
    if args.via_pilots:
        archs = (args.archs or f"{args.arch},gemma-2b").split(",")
        serve_via_pilots(archs, n_requests=args.requests, slots=args.slots,
                         max_len=args.max_len)
        return
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    draft_cfg = None
    if args.draft and args.draft != "self":
        draft_cfg = (get_smoke_config(args.draft) if args.smoke
                     else get_config(args.draft))
    stats = serve_direct(cfg, args.requests, args.slots or 4,
                         args.max_len or 128,
                         admission="wave" if args.wave else "continuous",
                         kv=args.kv, prefill=args.prefill,
                         num_blocks=args.num_blocks,
                         dup_rate=args.dup_rate,
                         spec="draft" if args.draft else "off",
                         spec_k=args.spec_k, draft_cfg=draft_cfg,
                         mesh_shape=mesh_shape)
    if mesh_shape is not None:
        print(f"[mesh] shape={'x'.join(map(str, mesh_shape))} "
              f"devices={stats['mesh_devices']} "
              f"kv_pool_bytes_per_device={stats['kv_pool_bytes_per_device']} "
              f"(total {stats['kv_pool_bytes']})")
    if args.draft:
        print(f"[spec] spec={stats['spec']} "
              f"acceptance_rate={stats['acceptance_rate']:.3f} "
              f"tokens_per_step={stats['tokens_per_step']:.2f} "
              f"draft_overhead_s={stats['draft_overhead_s']:.3f}")
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
