"""Batched-serving driver THROUGH the pilot system.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 16 --slots 4 [--via-pilots]

Default runs the engine directly; ``--via-pilots`` submits the engine run
as a ``serve`` payload so the whole request batch is late-bound onto a
pilot-held slice (and a second model can be served by the SAME pilot right
after — the multi-payload demo).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.core.cluster import ClusterSim
from repro.core.images import PayloadImage
from repro.core.pilot import PilotConfig
from repro.models.api import build_model
from repro.serving.engine import Request, ServeEngine


def serve_direct(cfg, n_requests: int, slots: int, max_len: int,
                 seed: int = 0) -> dict:
    params = build_model(cfg).init(jax.random.key(seed))
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len)
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, max_len // 4))),
            max_new_tokens=int(rng.integers(8, 24))))
    return eng.run()


def serve_via_pilots(archs: list[str], n_steps: int = 12) -> None:
    """Several serve payloads (different models!) multiplexed over ONE
    pilot — container late-binding for inference."""
    sim = ClusterSim()
    tids = [sim.repo.submit(PayloadImage(arch=a, shape="smoke", mode="decode"),
                            n_steps=n_steps) for a in archs]
    (s,) = sim.provision(1)
    pilot = sim.spawn_pilot(s, PilotConfig(max_payloads=len(archs) + 1,
                                           idle_grace=2.0))
    ok = sim.run_until_drained(timeout=600.0)
    sim.join_all(timeout=30.0)
    print(f"[serve] drained={ok} repo={sim.repo.stats()}")
    for tid, arch in zip(tids, archs):
        r = sim.repo.result(tid)
        if r:
            st = r.telemetry.get("step_times", [])
            print(f"  {arch}: {r.telemetry.get('steps')} decode steps, "
                  f"mean {np.mean(st)*1e3:.1f} ms/step "
                  f"(bind cached={pilot.history[tids.index(tid)].get('bind_cached')})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--archs", default=None,
                    help="comma list for --via-pilots multi-model demo")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--via-pilots", action="store_true")
    args = ap.parse_args()

    if args.via_pilots:
        archs = (args.archs or f"{args.arch},gemma-2b").split(",")
        serve_via_pilots(archs)
        return
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    stats = serve_direct(cfg, args.requests, args.slots, args.max_len)
    print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
