"""Step functions the pilot system compiles: train_step / prefill / serve.

These are the "container images" of the late-binding analogy: a
(cfg x shape x mesh x step-kind) tuple keys the ExecutableRegistry compile
cache, and `PayloadExecutor.bind()` installs the compiled artifact on an
already-held slice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.api import build_model
from repro.optim.adamw import OptimConfig, adamw_update, init_opt_state


def make_train_step(cfg, oc: OptimConfig | None = None,
                    grad_transform=None):
    """(state, batch) -> (state, metrics); state = {"params", "opt"}."""
    oc = oc or OptimConfig()
    bundle = build_model(cfg)

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            bundle.loss, has_aux=True)(state["params"], batch)
        new_p, new_opt, om = adamw_update(state["params"], grads,
                                          state["opt"], oc,
                                          grad_transform=grad_transform)
        return ({"params": new_p, "opt": new_opt},
                {"loss": loss, **metrics, **om})

    return train_step


def make_prefill_step(cfg):
    bundle = build_model(cfg)

    def prefill_step(params, batch):
        return bundle.prefill(params, batch)

    return prefill_step


def make_serve_step(cfg):
    """One decode step: (params, state) -> (logits, state)."""
    bundle = build_model(cfg)

    def serve_step(params, state):
        return bundle.decode(params, state)

    return serve_step


def init_train_state(cfg, key):
    bundle = build_model(cfg)
    params = bundle.init(key)
    return {"params": params, "opt": init_opt_state(params)}
