"""AdamW + global-norm clipping + cosine schedule, sharding-preserving.

Optimizer moments are pytrees with the same structure (and the same
shardings) as the parameters, so ZeRO-3/FSDP sharding extends to the full
optimizer state for free.  An optional gradient-compression hook (int8
error-feedback, `repro.runtime.compression`) plugs in between grad and
update — a beyond-paper distributed-optimization feature.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(oc: OptimConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    prog = (step - oc.warmup_steps) / jnp.maximum(
        oc.total_steps - oc.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.peak_lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state, oc: OptimConfig,
                 grad_transform: Callable[[Any], Any] | None = None):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    if grad_transform is not None:
        grads = grad_transform(grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cosine_lr(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_dir = mh / (jnp.sqrt(vh) + oc.eps)
        newp = p.astype(jnp.float32) - lr * (step_dir + oc.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
