from repro.optim.adamw import OptimConfig, adamw_update, cosine_lr, global_norm, init_opt_state

__all__ = ["OptimConfig", "adamw_update", "cosine_lr", "global_norm", "init_opt_state"]
