"""Repo-specific AST lint for the concurrency + transfer invariants.

The rules encode discipline that general-purpose linters cannot know:

``bare-lock``
    No ``threading.Lock()`` / ``RLock()`` / ``Condition()`` outside
    :mod:`repro.analysis` — every lock must come from the instrumented
    factory (``make_lock`` / ``make_rlock`` / ``make_condition``) so the
    auditor and the schedule fuzzer see it.

``wallclock-in-step``
    No ``time.time()`` / ``datetime.now()`` / ``utcnow()`` inside jitted
    step builders (functions named ``make_*step`` or decorated with
    ``jax.jit``): a traced wall-clock read bakes one timestamp into the
    compiled step forever.

``one-transfer``
    The serve engine's step path performs EXACTLY ONE device->host
    transfer per step (the packed result read).  Statically: no
    ``jax.device_get`` / ``.item()`` / ``np.asarray`` / ``np.array`` in
    ``ServeEngine.step`` or the ``make_*step`` builders in
    ``serving/engine.py`` outside the whitelisted (suppressed) single
    transfer.

``blocking-under-lock``
    No ``time.sleep`` / ``<x>.wait(...)`` / ``<x>.join(...)`` lexically
    inside a ``with <lock-like>:`` block.  A condition waiting on
    *itself* (``with self._cond: ... self._cond.wait()``) is the one
    legal shape and is auto-allowed — provided no OTHER lock-like
    context is active, since ``wait`` releases only its own lock.

Suppression syntax (same line or the line above)::

    something_flagged()   # lint: allow[rule-id] -- why this is safe

The justification after ``--`` is REQUIRED: an ``allow`` without one is
itself an (unsuppressable) finding, so zero silent suppressions survive
CI.  Multiple rules: ``allow[rule-a,rule-b] -- ...``.

CLI::

    python -m repro.analysis.lint src tests benchmarks
    # exit 1 if any unsuppressed finding; --show-suppressed lists the rest
"""

from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import List, Optional

RULES = {
    "bare-lock": "threading lock constructed outside repro.analysis.locks",
    "wallclock-in-step": "wall-clock read inside a jitted step builder",
    "one-transfer": "device->host transfer in an engine step path",
    "blocking-under-lock": "blocking call under a held lock",
    "bad-suppression": "lint suppression without a justification",
}

_ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\[([\w,\- ]+)\]\s*(?:--\s*(\S.*))?")
_LOCKISH_RE = re.compile(r"lock|cond|mutex", re.IGNORECASE)
# context managers that merely *mention* locks — the auditor installs
# instrumentation, it doesn't hold a lock across its body
_NOT_LOCKISH_RE = re.compile(r"auditor", re.IGNORECASE)
_STEP_BUILDER_RE = re.compile(r"^make_\w*step$")
_LOCK_CTORS = {"Lock", "RLock", "Condition"}


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str
    suppressed: bool = False
    justification: Optional[str] = None

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"


def _is_jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        try:
            txt = ast.unparse(target)
        except Exception:  # noqa: BLE001
            continue
        if txt in ("jax.jit", "jit", "functools.partial(jax.jit"):
            return True
        if "jax.jit" in txt:
            return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, in_analysis: bool, in_engine: bool):
        self.path = path
        self.in_analysis = in_analysis      # repro/analysis is exempt
        self.in_engine = in_engine          # serving/engine.py step scope
        self.findings: List[Finding] = []
        self._threading_aliases = {"threading"}
        self._lock_ctor_names: set = set()  # from-imported ctor names
        self._fn_stack: List[dict] = []
        self._class_stack: List[str] = []
        # stack of active lock-like with-context expressions (unparsed)
        self._with_locks: List[str] = []

    # -- helpers -------------------------------------------------------

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(
            Finding(self.path, getattr(node, "lineno", 0), rule, message))

    def _in_step_builder(self) -> bool:
        return any(f["step_builder"] for f in self._fn_stack)

    def _in_engine_step(self) -> bool:
        if not self.in_engine:
            return False
        return any(f["engine_step"] or f["step_builder"]
                   for f in self._fn_stack)

    # -- imports -------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            if a.name == "threading":
                self._threading_aliases.add(a.asname or "threading")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "threading":
            for a in node.names:
                if a.name in _LOCK_CTORS:
                    self._lock_ctor_names.add(a.asname or a.name)
        self.generic_visit(node)

    # -- scopes --------------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_fn(self, node) -> None:
        self._fn_stack.append({
            "step_builder": (bool(_STEP_BUILDER_RE.match(node.name))
                             or _is_jit_decorated(node)),
            "engine_step": (node.name == "step"
                            and bool(self._class_stack)
                            and self._class_stack[-1] == "ServeEngine"),
        })
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            try:
                txt = ast.unparse(item.context_expr)
            except Exception:  # noqa: BLE001
                continue
            if _LOCKISH_RE.search(txt) and not _NOT_LOCKISH_RE.search(txt):
                self._with_locks.append(txt)
                pushed += 1
        self.generic_visit(node)
        for _ in range(pushed):
            self._with_locks.pop()

    # -- calls ---------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        name_txt = None
        try:
            name_txt = ast.unparse(func)
        except Exception:  # noqa: BLE001
            pass

        # bare-lock: threading.Lock() / Lock() via from-import
        if not self.in_analysis:
            if (isinstance(func, ast.Attribute)
                    and func.attr in _LOCK_CTORS
                    and isinstance(func.value, ast.Name)
                    and func.value.id in self._threading_aliases):
                self._emit(node, "bare-lock",
                           f"threading.{func.attr}() — use repro.analysis."
                           f"locks.make_{func.attr.lower()} so the auditor "
                           f"and schedule fuzzer can see this lock")
            elif (isinstance(func, ast.Name)
                  and func.id in self._lock_ctor_names):
                self._emit(node, "bare-lock",
                           f"{func.id}() imported from threading — use the "
                           f"repro.analysis.locks factory")

        # wallclock-in-step
        if self._in_step_builder() and name_txt in (
                "time.time", "datetime.now", "datetime.datetime.now",
                "datetime.utcnow", "datetime.datetime.utcnow"):
            self._emit(node, "wallclock-in-step",
                       f"{name_txt}() inside a jitted step builder bakes "
                       f"one timestamp into the compiled step")

        # one-transfer (engine.py step paths only)
        if self._in_engine_step():
            if name_txt in ("jax.device_get", "np.asarray", "np.array",
                            "numpy.asarray", "numpy.array"):
                self._emit(node, "one-transfer",
                           f"{name_txt}() in an engine step path — the step "
                           f"performs exactly one device->host transfer")
            elif (isinstance(func, ast.Attribute) and func.attr == "item"
                  and not node.args and not node.keywords):
                self._emit(node, "one-transfer",
                           ".item() in an engine step path — implicit "
                           "device->host transfer")

        # blocking-under-lock
        if self._with_locks:
            blocked = None
            if name_txt == "time.sleep":
                blocked = "time.sleep"
            elif isinstance(func, ast.Attribute) and func.attr in (
                    "wait", "join"):
                try:
                    target = ast.unparse(func.value)
                except Exception:  # noqa: BLE001
                    target = None
                # the one legal shape: a condition waiting on ITSELF with
                # no other lock-like context active (wait releases only
                # its own lock)
                if not (func.attr == "wait"
                        and target is not None
                        and target in self._with_locks
                        and len(self._with_locks) == 1):
                    blocked = f"{target or '?'}.{func.attr}"
            if blocked is not None:
                self._emit(node, "blocking-under-lock",
                           f"{blocked}(...) while holding "
                           f"{self._with_locks[-1]!r} — blocks every other "
                           f"thread contending for the lock")

        self.generic_visit(node)


def _apply_suppressions(findings: List[Finding], lines: List[str],
                        path: str) -> List[Finding]:
    out: List[Finding] = []
    for f in findings:
        allow = None
        for ln in (f.line, f.line - 1):
            if 1 <= ln <= len(lines):
                m = _ALLOW_RE.search(lines[ln - 1])
                if m:
                    allow = (m.group(1), m.group(2), ln)
                    break
        if allow is None:
            out.append(f)
            continue
        rules = {r.strip() for r in allow[0].split(",")}
        if f.rule not in rules:
            out.append(f)
            continue
        if not allow[1] or not allow[1].strip():
            out.append(f)
            out.append(Finding(
                path, allow[2], "bad-suppression",
                f"allow[{f.rule}] without a justification — write "
                f"`# lint: allow[{f.rule}] -- <why this is safe>`"))
            continue
        f.suppressed = True
        f.justification = allow[1].strip()
        out.append(f)
    return out


def lint_source(src: str, path: str = "<string>") -> List[Finding]:
    """Lint one source string; returns all findings (suppressed included)."""
    posix = Path(path).as_posix()
    in_analysis = "repro/analysis/" in posix
    in_engine = posix.endswith("serving/engine.py")
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, "bad-suppression",
                        f"syntax error: {e.msg}")]
    v = _Visitor(path, in_analysis, in_engine)
    v.visit(tree)
    return _apply_suppressions(v.findings, src.splitlines(), path)


def lint_paths(paths: List[str]) -> List[Finding]:
    findings: List[Finding] = []
    for root in paths:
        p = Path(root)
        files = ([p] if p.is_file()
                 else sorted(f for f in p.rglob("*.py")
                             if "__pycache__" not in f.parts))
        for f in files:
            findings.extend(
                lint_source(f.read_text(encoding="utf-8"), str(f)))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific concurrency/transfer lint")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also list suppressed findings with justifications")
    args = ap.parse_args(argv)

    findings = lint_paths(args.paths)
    unsuppressed = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    for f in unsuppressed:
        print(f.format())
    if args.show_suppressed:
        for f in suppressed:
            print(f"{f.format()} -- {f.justification}")
    print(f"lint: {len(unsuppressed)} finding(s), "
          f"{len(suppressed)} suppressed, "
          f"{len(set(f.path for f in findings)) if findings else 0} file(s) "
          f"with findings")
    return 1 if unsuppressed else 0


if __name__ == "__main__":
    sys.exit(main())
