"""Deterministic schedule fuzzer: seeded preemption at lock boundaries.

Race bugs hide in interleavings the test suite never hits.  The fuzzer
widens the explored schedule space *reproducibly*: a
:class:`ScheduleFuzzer` plugs into the lock auditor's ``preempt`` hook,
and at every tracked acquire/release/wait boundary each thread consults
its own seeded RNG — ``Random(seed ^ crc32(thread_name))`` — to decide
whether to yield (a tiny sleep, plus a lowered ``sys.setswitchinterval``
to amplify contention).  The per-thread *decision sequence* is a pure
function of ``(seed, thread name, boundary index)``, so a failing seed
replays the same injected-preemption schedule; the OS still owns actual
thread placement, so this is deterministic *injection*, not a
deterministic scheduler — in practice a failing seed reproduces because
the injected yields dominate the interleaving.

The driven workload is the PR-7 six-server stress race
(:func:`six_server_stress`): N requests raced by six server threads that
randomly complete, release, die silently (lease expiry + replay), or
hold-and-renew, under an aggressive hedging watchdog — now with a
:class:`~repro.serving.blockpool.BlockAllocator` churn per held request
so "zero block leaks" is an asserted invariant, not a vacuous one.
Every seed asserts:

- exactly-once settlement (every rid completed once, zero failed,
  accepted-counts all exactly 1, token streams correct);
- zero stranded leases (repo queued == leased == 0, no lease holders);
- zero block leaks (the allocator is fully free at the end);
- zero lock-order cycles and zero auditor violations.

CLI::

    python -m repro.analysis.fuzz --seeds 10          # the soak gate
    python -m repro.analysis.fuzz --seeds 3 --requests 24   # CI smoke
    python -m repro.analysis.fuzz --seeds 1 --table   # lock-order table
"""

from __future__ import annotations

import random
import sys
import threading
import time
import zlib
from typing import Any, Dict, List, Optional

from repro.analysis.locks import LockAuditor, make_lock

__all__ = ["ScheduleFuzzer", "six_server_stress", "run_soak", "main"]


class ScheduleFuzzer:
    """Seeded preemption injector for the lock auditor's ``preempt`` hook.

    ``decisions`` maps thread name -> the 0/1 preemption choices made at
    each of that thread's lock boundaries, in order — the reproducibility
    witness (same seed => identical per-thread decision sequences).
    """

    def __init__(self, seed: int, *, p_preempt: float = 0.15,
                 sleep_s: float = 0.0003):
        self.seed = int(seed)
        self.p_preempt = p_preempt
        self.sleep_s = sleep_s
        self.preemptions = 0
        self.boundaries = 0
        self._tl = threading.local()
        self._mu = threading.Lock()  # lint: allow[bare-lock] -- the fuzzer feeds the auditor's preempt hook; a tracked lock here would recurse into instrumentation
        self.decisions: Dict[str, List[int]] = {}

    def _state(self):
        st = getattr(self._tl, "state", None)
        if st is None:
            name = threading.current_thread().name
            rng = random.Random(
                (self.seed << 17) ^ zlib.crc32(name.encode()))
            with self._mu:
                trace = self.decisions.setdefault(name, [])
            st = self._tl.state = (rng, trace)
        return st

    def preempt(self, point: str, lock: Any) -> None:
        rng, trace = self._state()
        hit = rng.random() < self.p_preempt
        trace.append(1 if hit else 0)
        self.boundaries += 1        # benign race: approximate counters
        if hit:
            self.preemptions += 1
            if self.sleep_s > 0:
                time.sleep(self.sleep_s)

    def auditor(self) -> LockAuditor:
        return LockAuditor(preempt=self.preempt)


def six_server_stress(seed: int, *, n_requests: int = 40,
                      n_servers: int = 6, p_preempt: float = 0.15,
                      sleep_s: float = 0.0003,
                      timeout: float = 120.0) -> Dict[str, Any]:
    """One fuzzed run of the six-server stress race.  Raises
    AssertionError (with the full auditor report) on any invariant
    violation; returns a summary dict on success."""
    # imported here, not at module top: analysis.locks must stay
    # importable from every core module without dragging in serving
    from repro.core.taskrepo import BackoffPolicy
    from repro.serving.blockpool import BlockAllocator
    from repro.serving.dispatch import FleetDispatcher, RobustnessPolicy

    fz = ScheduleFuzzer(seed, p_preempt=p_preempt, sleep_s=sleep_s)
    aud = fz.auditor()
    pol = RobustnessPolicy(
        stall_deadline=0.0, sick_cooldown=0.0,
        hedging=True, hedge_percentile=50.0, hedge_factor=3.0,
        hedge_min_s=0.15, hedge_min_samples=4, max_hedges=2,
        watchdog_interval=0.02, quarantine_after=0,
        backoff=BackoffPolicy(base=0.01, cap=0.1))
    alloc = BlockAllocator(num_blocks=1 + 4 * n_requests, block_size=16)
    accepted: Dict[int, int] = {}
    acc_lock = make_lock("fuzz.accounting")

    def tokens_for(rid: int) -> List[int]:
        return [rid, rid + 1, rid + 2]

    old_si = sys.getswitchinterval()
    t0 = time.monotonic()
    aud.install()
    pool = None
    try:
        sys.setswitchinterval(1e-4)
        pool = FleetDispatcher(name=f"fuzz-pool-{seed}", lease_ttl=0.12,
                               max_attempts=64, policy=pol)

        def server(name: str, srv_seed: int):
            rng = random.Random(srv_seed)
            held: Dict[int, List[int]] = {}   # rid -> leased KV blocks

            def free_blocks(rid: int):
                for bid in held.pop(rid, []):
                    alloc.free(bid)

            while not pool.finished():
                got = pool.fetch(name, max_n=2, timeout=0.05)
                for e in got:
                    held[e["rid"]] = [alloc.alloc() for _ in range(2)]
                if not got:
                    continue
                for e in got:
                    rid = e["rid"]
                    roll = rng.random()
                    if roll < 0.45:
                        ok = pool.complete(
                            name, rid, tokens_for(rid),
                            first_token_s=0.01)
                        free_blocks(rid)
                        if ok:
                            with acc_lock:
                                accepted[rid] = accepted.get(rid, 0) + 1
                    elif roll < 0.65:
                        pool.release(name, [rid])
                        free_blocks(rid)
                    elif roll < 0.8:
                        # silent death: never release the lease — the
                        # reaper requeues it.  The pilot's device blocks
                        # die with it, so the harness frees them here.
                        free_blocks(rid)
                    else:
                        # slow holder: renew a few times, then finish
                        for _ in range(rng.randint(1, 3)):
                            time.sleep(0.02)
                            lost = pool.renew(name, {rid: 1})
                            if rid in lost:
                                break
                        else:
                            ok = pool.complete(
                                name, rid, tokens_for(rid),
                                first_token_s=0.05)
                            if ok:
                                with acc_lock:
                                    accepted[rid] = accepted.get(rid, 0) + 1
                        free_blocks(rid)
            for rid in list(held):
                free_blocks(rid)

        for rid in range(n_requests):
            pool.submit({"rid": rid, "prompt": [1, 2, 3],
                         "max_new_tokens": 3})
        pool.seal()
        threads = [
            threading.Thread(target=server,
                             args=(f"fuzz-server-{i}", (seed << 8) + i),
                             name=f"fuzz-server-{i}", daemon=True)
            for i in range(n_servers)
        ]
        for t in threads:
            t.start()
        settled = pool.wait_all(timeout)
        for t in threads:
            t.join(timeout=10.0)

        errors: List[str] = []
        st = pool.stats()
        if not settled:
            errors.append(f"wait_all timed out after {timeout}s: {st}")
        if st["completed"] != n_requests:
            errors.append(
                f"completed {st['completed']} != {n_requests} submitted")
        if st["failed"] != 0:
            errors.append(f"{st['failed']} requests settled failed")
        multi = {r: n for r, n in accepted.items() if n != 1}
        if multi:
            errors.append(f"non-exactly-once acceptance: {multi}")
        results = pool.results()
        bad = [r for r, toks in results.items() if toks != tokens_for(r)]
        if bad:
            errors.append(f"wrong tokens for rids {bad}")
        rs = pool.repo.stats()
        if rs["queued"] != 0 or rs["leased"] != 0:
            errors.append(
                f"stranded repo state: queued={rs['queued']} "
                f"leased={rs['leased']}")
        holders = pool.lease_holders()
        if holders:
            errors.append(f"stranded lease holders: {holders}")
        if alloc.allocated_blocks != 0:
            errors.append(
                f"block leak: {alloc.allocated_blocks} blocks still "
                f"allocated of {alloc.capacity_blocks}")
        rep = aud.report()
        if rep["cycles"]:
            errors.append(f"{len(rep['cycles'])} lock-order cycle(s)")
        if rep["violations"]:
            errors.append(f"{len(rep['violations'])} auditor violation(s)")
        if errors:
            raise AssertionError(
                f"seed {seed}: " + "; ".join(errors) + "\n"
                + aud.format_report(rep))
        return {
            "seed": seed,
            "completed": st["completed"],
            "replays": st["replays"],
            "hedges": st["hedges"],
            "duplicates": st["duplicates"],
            "lost_leases": st["lost_leases"],
            "boundaries": fz.boundaries,
            "preemptions": fz.preemptions,
            "lock_acquisitions": aud.acquired_total,
            "order_edges": rep["n_edges"],
            "table": rep["table"],
            "wall_s": time.monotonic() - t0,
        }
    finally:
        sys.setswitchinterval(old_si)
        if pool is not None:
            pool.close()
        aud.uninstall()


def run_soak(seeds: List[int], **kw: Any) -> List[Dict[str, Any]]:
    """Run the stress race under every seed; raises on the first failure."""
    return [six_server_stress(s, **kw) for s in seeds]


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.fuzz",
        description="deterministic schedule fuzzer (six-server stress race)")
    ap.add_argument("--seeds", default="10",
                    help="seed count N (runs 0..N-1) or comma list")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--servers", type=int, default=6)
    ap.add_argument("--p-preempt", type=float, default=0.15)
    ap.add_argument("--table", action="store_true",
                    help="print the observed lock-hierarchy table")
    args = ap.parse_args(argv)

    if "," in args.seeds:
        seeds = [int(s) for s in args.seeds.split(",") if s.strip()]
    else:
        seeds = list(range(int(args.seeds)))

    table: Dict[str, List[str]] = {}
    for s in seeds:
        r = six_server_stress(s, n_requests=args.requests,
                              n_servers=args.servers,
                              p_preempt=args.p_preempt)
        for src, dsts in r["table"].items():
            table.setdefault(src, [])
            table[src] = sorted(set(table[src]) | set(dsts))
        print(f"seed {r['seed']:>3}: completed={r['completed']} "
              f"replays={r['replays']} hedges={r['hedges']} "
              f"duplicates={r['duplicates']} "
              f"preempts={r['preemptions']}/{r['boundaries']} "
              f"edges={r['order_edges']} wall={r['wall_s']:.1f}s")
    print(f"fuzz: {len(seeds)} seed(s) clean — exactly-once settlement, "
          f"zero stranded leases, zero block leaks, zero cycles")
    if args.table:
        print("observed lock order (held -> acquired):")
        for src, dsts in sorted(table.items()):
            print(f"  {src} -> {', '.join(dsts)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
