"""Instrumented locking layer: drop-in Lock/RLock/Condition with auditing.

Every lock in ``core/`` and ``serving/`` is created through this module's
factory (``make_lock`` / ``make_rlock`` / ``make_condition``) instead of
bare ``threading.*`` — the repo lint enforces this.  A tracked lock is a
thin wrapper over the stdlib primitive whose hot path costs one module
attribute read when no auditor is installed (the same idiom as
``chaos.site``).  With a :class:`LockAuditor` installed, every
acquisition records:

- the per-thread **held-set** at the moment of acquisition,
- an **edge** ``held -> acquired`` into a global lock-order graph
  (instance-granular, so the disagg prefill->decode pool chain — two
  *different* pool locks taken in a fixed order — is not a false cycle),
- the **witness stack** the first time each edge is seen,
- **hierarchy violations**: the documented order is pool -> repo -> wheel
  (``RANK_POOL < RANK_REPO < RANK_WHEEL``); acquiring a lower-ranked
  lock while holding a higher-ranked one is flagged,
- **blocking-under-lock**: ``Condition.wait`` while holding any *other*
  tracked lock,
- **callback-under-lock**: ``audit_callback(site)`` is called by the
  runtime immediately before invoking user-supplied hooks (timer-wheel
  callbacks, ``on_complete``, ``on_expired``, proc-table listeners,
  executor ``on_exit``); if any tracked lock is held at that point the
  auditor records a violation.

The auditor also exposes a ``preempt`` hook fired at every tracked
acquire/release/wait boundary — the deterministic schedule fuzzer
(:mod:`repro.analysis.fuzz`) uses it to inject seeded context switches.

Lock-ranks are coarse *classes*; cycle detection runs on instances.  A
rank of ``None`` means "leaf / unranked": the lock participates in the
graph but not in the rank check.
"""

from __future__ import annotations

import itertools
import threading
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "RANK_POOL",
    "RANK_REPO",
    "RANK_WHEEL",
    "TrackedLock",
    "TrackedRLock",
    "TrackedCondition",
    "LockAuditor",
    "make_lock",
    "make_rlock",
    "make_condition",
    "audit_callback",
    "current_auditor",
]

# Documented acquisition order: a pool lock may be held while taking the
# repo lock (dispatch fetch/complete/release all call into TaskRepo with
# the pool lock held), and the repo lock may be held while taking the
# timer-wheel lock (arming defer/reap timers).  Never the reverse.
RANK_POOL = 10
RANK_REPO = 20
RANK_WHEEL = 30

_RANK_NAMES = {RANK_POOL: "pool", RANK_REPO: "repo", RANK_WHEEL: "wheel"}

# The one module-global the hot path reads.  None => auditing off.
_AUDITOR: Optional["LockAuditor"] = None
_INSTALL_LOCK = threading.Lock()
_SEQ = itertools.count(1)


def current_auditor() -> Optional["LockAuditor"]:
    """The currently installed auditor, or None."""
    return _AUDITOR


def audit_callback(site: str) -> None:
    """Runtime guard: call immediately before invoking a user callback.

    Records a ``callback-under-lock`` violation if the calling thread
    holds any tracked lock.  One attr read when auditing is off.
    """
    a = _AUDITOR
    if a is not None:
        a.note_callback(site)


class TrackedLock:
    """Non-reentrant mutex wrapping ``threading.Lock``.

    Defines ``_is_owned`` (via explicit owner tracking) so it can back a
    ``threading.Condition`` — the stdlib default probes ownership with a
    nonblocking acquire, which would corrupt our bookkeeping.
    """

    __slots__ = ("_inner", "name", "rank", "seq", "_owner")

    reentrant = False

    def __init__(self, name: str, rank: Optional[int] = None):
        self._inner = threading.Lock()
        self.name = name
        self.rank = rank
        self.seq = next(_SEQ)
        self._owner = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        a = _AUDITOR
        if a is not None:
            a.before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
            if a is not None:
                a.on_acquired(self)
        return got

    def release(self) -> None:
        # Owner cleared before the inner release so a racing acquirer
        # never observes itself as a stale owner.
        self._owner = 0
        self._inner.release()
        a = _AUDITOR
        if a is not None:
            a.on_released(self)

    def locked(self) -> bool:
        return self._inner.locked()

    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    # Condition support: with _is_owned defined, the stdlib default
    # _release_save/_acquire_restore (plain release/acquire) are correct
    # and route through our tracked acquire/release.

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<TrackedLock {self.name!r} seq={self.seq} rank={self.rank}>"


class TrackedRLock:
    """Reentrant mutex wrapping ``threading.RLock``.

    Only the *outermost* acquire/release of a reentrant hold is reported
    to the auditor — nested re-acquisition by the owning thread is not an
    ordering event and must not create self-edges.
    """

    __slots__ = ("_inner", "name", "rank", "seq", "_owner", "_count")

    reentrant = True

    def __init__(self, name: str, rank: Optional[int] = None):
        self._inner = threading.RLock()
        self.name = name
        self.rank = rank
        self.seq = next(_SEQ)
        self._owner = 0
        self._count = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        a = _AUDITOR
        first = self._owner != threading.get_ident()
        if a is not None and first:
            a.before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
            self._count += 1
            if a is not None and first:
                a.on_acquired(self)
        return got

    def release(self) -> None:
        self._count -= 1
        last = self._count == 0
        if last:
            self._owner = 0
        self._inner.release()
        if last:
            a = _AUDITOR
            if a is not None:
                a.on_released(self)

    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    # Condition support for reentrant locks: wait() must fully release
    # the recursion and restore it on wakeup.
    def _release_save(self) -> Tuple[Any, int]:
        count = self._count
        self._count = 0
        self._owner = 0
        state = self._inner._release_save()
        a = _AUDITOR
        if a is not None:
            a.on_released(self)
        return (state, count)

    def _acquire_restore(self, saved: Tuple[Any, int]) -> None:
        state, count = saved
        a = _AUDITOR
        if a is not None:
            a.before_acquire(self)
        self._inner._acquire_restore(state)
        self._owner = threading.get_ident()
        self._count = count
        if a is not None:
            a.on_acquired(self)

    def __enter__(self) -> "TrackedRLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<TrackedRLock {self.name!r} seq={self.seq} rank={self.rank}>"


class TrackedCondition(threading.Condition):
    """``threading.Condition`` over a tracked lock.

    Reuses the stdlib wait/notify machinery (it duck-types through the
    lock's ``acquire``/``release``/``_is_owned``/``_release_save``/
    ``_acquire_restore``), adding only the wait-under-lock check and the
    fuzzer preemption point.
    """

    def __init__(self, lock: Any):
        if not isinstance(lock, (TrackedLock, TrackedRLock)):
            raise TypeError("TrackedCondition requires a tracked lock")
        super().__init__(lock)

    def wait(self, timeout: Optional[float] = None) -> bool:
        a = _AUDITOR
        if a is not None:
            a.note_wait(self._lock)
        return super().wait(timeout)


def make_lock(name: str, *, rank: Optional[int] = None) -> TrackedLock:
    """Factory for a non-reentrant tracked mutex."""
    return TrackedLock(name, rank)


def make_rlock(name: str, *, rank: Optional[int] = None) -> TrackedRLock:
    """Factory for a reentrant tracked mutex."""
    return TrackedRLock(name, rank)


def make_condition(
    lock: Any = None, *, name: str = "condition", rank: Optional[int] = None
) -> TrackedCondition:
    """Factory for a condition variable over a tracked lock.

    With ``lock=None`` a fresh ``TrackedRLock`` backs the condition
    (matching the stdlib default of an RLock).  Pass an existing tracked
    lock to share it between plain ``with`` sections and the condition —
    the usual repo/pool pattern.
    """
    if lock is None:
        lock = TrackedRLock(name, rank)
    return TrackedCondition(lock)


class LockAuditor:
    """Records lock acquisition order and concurrency-discipline violations.

    Install with ``install()`` / ``uninstall()`` or as a context manager.
    Installation nests: installing while another auditor is active stashes
    the previous one and restores it on uninstall, so tests can run a
    private auditor under the session-wide ``--concurrency-audit`` one.

    Violation kinds recorded in ``violations`` (list of dicts):

    - ``self-deadlock``   — re-acquire of a non-reentrant lock the thread
      already owns (also raised as RuntimeError: the acquire would hang).
    - ``lock-hierarchy``  — acquired a lower-ranked lock while holding a
      higher-ranked one (pool -> repo -> wheel is the documented order).
    - ``wait-under-lock`` — Condition.wait while holding another tracked
      lock (wait releases only its own lock; the rest block strangers).
    - ``callback-under-lock`` — user hook invoked with a tracked lock held
      (see ``audit_callback``).

    ``preempt``, if set, is called as ``preempt(point, lock)`` with
    ``point`` in {"acquire", "release", "wait"} at every boundary — the
    schedule fuzzer's injection point.
    """

    def __init__(
        self,
        *,
        preempt: Optional[Callable[[str, Any], None]] = None,
        stack_limit: int = 14,
    ):
        # Raw stdlib lock on purpose: the auditor's own mutex must not
        # feed back into the graph it maintains.
        self._mu = threading.Lock()
        self._tl = threading.local()
        # (src_seq, dst_seq) -> edge record
        self._edges: Dict[Tuple[int, int], Dict[str, Any]] = {}
        self.violations: List[Dict[str, Any]] = []
        self.acquired_total = 0  # benign data race: approximate counter
        self.preempt = preempt
        self.stack_limit = stack_limit
        self._prev: Optional["LockAuditor"] = None

    # -- installation -------------------------------------------------

    def install(self) -> "LockAuditor":
        global _AUDITOR
        with _INSTALL_LOCK:
            self._prev = _AUDITOR
            _AUDITOR = self
        return self

    def uninstall(self) -> None:
        global _AUDITOR
        with _INSTALL_LOCK:
            if _AUDITOR is self:
                _AUDITOR = self._prev
            self._prev = None

    def __enter__(self) -> "LockAuditor":
        return self.install()

    def __exit__(self, *exc: Any) -> None:
        self.uninstall()

    # -- per-thread state ---------------------------------------------

    def _held(self) -> List[Any]:
        h = getattr(self._tl, "held", None)
        if h is None:
            h = self._tl.held = []
        return h

    def held_names(self) -> List[str]:
        """Names of tracked locks held by the calling thread."""
        return [h.name for h in self._held()]

    # -- event sinks (called from tracked locks) ----------------------

    def before_acquire(self, lock: Any) -> None:
        if self.preempt is not None:
            self.preempt("acquire", lock)
        held = self._held()
        if not held:
            return
        if not lock.reentrant and lock._is_owned():
            self._violate(
                "self-deadlock",
                f"thread re-acquired non-reentrant lock {lock.name!r} "
                f"it already holds",
            )
            raise RuntimeError(
                f"self-deadlock: {lock.name!r} is non-reentrant and already "
                f"held by this thread"
            )
        if lock.rank is not None:
            worst = None
            for h in held:
                if h.rank is not None and h.rank > lock.rank:
                    if worst is None or h.rank > worst.rank:
                        worst = h
            if worst is not None:
                self._violate(
                    "lock-hierarchy",
                    f"acquired {lock.name!r} "
                    f"({_RANK_NAMES.get(lock.rank, lock.rank)}) while holding "
                    f"{worst.name!r} ({_RANK_NAMES.get(worst.rank, worst.rank)}) "
                    f"— documented order is pool -> repo -> wheel",
                )
        for h in held:
            if h is lock:
                continue
            self._edge(h, lock)

    def on_acquired(self, lock: Any) -> None:
        self.acquired_total += 1
        self._held().append(lock)

    def on_released(self, lock: Any) -> None:
        held = self._held()
        # Out-of-LIFO release is legal; drop the most recent occurrence.
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                break
        if self.preempt is not None:
            self.preempt("release", lock)

    def note_wait(self, lock: Any) -> None:
        others = [h for h in self._held() if h is not lock]
        if others:
            self._violate(
                "wait-under-lock",
                f"Condition.wait on {lock.name!r} while still holding "
                f"{[h.name for h in others]!r}",
            )
        if self.preempt is not None:
            self.preempt("wait", lock)

    def note_callback(self, site: str) -> None:
        held = self._held()
        if held:
            self._violate(
                "callback-under-lock",
                f"user callback {site!r} invoked while holding "
                f"{[h.name for h in held]!r}",
            )

    # -- graph bookkeeping --------------------------------------------

    def _edge(self, src: Any, dst: Any) -> None:
        key = (src.seq, dst.seq)
        rec = self._edges.get(key)
        if rec is not None:
            rec["count"] += 1  # benign race on the counter
            return
        stack = "".join(
            traceback.format_stack(limit=self.stack_limit)[:-2]
        )
        with self._mu:
            rec = self._edges.get(key)
            if rec is not None:
                rec["count"] += 1
                return
            self._edges[key] = {
                "src": src.name,
                "dst": dst.name,
                "src_seq": src.seq,
                "dst_seq": dst.seq,
                "count": 1,
                "thread": threading.current_thread().name,
                "stack": stack,
            }

    def _violate(self, kind: str, message: str) -> None:
        stack = "".join(traceback.format_stack(limit=self.stack_limit)[:-2])
        with self._mu:
            self.violations.append(
                {
                    "kind": kind,
                    "message": message,
                    "thread": threading.current_thread().name,
                    "stack": stack,
                }
            )

    # -- reporting ----------------------------------------------------

    def edges(self) -> List[Dict[str, Any]]:
        with self._mu:
            return list(self._edges.values())

    def cycles(self) -> List[List[Dict[str, Any]]]:
        """Instance-level cycles in the acquisition graph.

        Each cycle is returned as the list of edge records along it
        (with witness stacks).  Uses iterative Tarjan SCC: any strongly
        connected component with more than one node is a potential
        deadlock.
        """
        with self._mu:
            edges = dict(self._edges)
        adj: Dict[int, List[int]] = {}
        for (s, d) in edges:
            adj.setdefault(s, []).append(d)
            adj.setdefault(d, [])
        index: Dict[int, int] = {}
        low: Dict[int, int] = {}
        on_stack: Dict[int, bool] = {}
        stack: List[int] = []
        sccs: List[List[int]] = []
        counter = itertools.count()

        for root in adj:
            if root in index:
                continue
            work: List[Tuple[int, int]] = [(root, 0)]
            while work:
                node, pi = work[-1]
                if pi == 0:
                    index[node] = low[node] = next(counter)
                    stack.append(node)
                    on_stack[node] = True
                recurse = False
                succs = adj[node]
                while pi < len(succs):
                    w = succs[pi]
                    pi += 1
                    if w not in index:
                        work[-1] = (node, pi)
                        work.append((w, 0))
                        recurse = True
                        break
                    elif on_stack.get(w):
                        low[node] = min(low[node], index[w])
                if recurse:
                    continue
                work[-1] = (node, pi)
                if low[node] == index[node]:
                    comp = []
                    while True:
                        w = stack.pop()
                        on_stack[w] = False
                        comp.append(w)
                        if w == node:
                            break
                    if len(comp) > 1:
                        sccs.append(comp)
                work.pop()
                if work:
                    parent, _ = work[-1]
                    low[parent] = min(low[parent], low[node])

        out: List[List[Dict[str, Any]]] = []
        for comp in sccs:
            members = set(comp)
            out.append(
                [
                    rec
                    for (s, d), rec in edges.items()
                    if s in members and d in members
                ]
            )
        return out

    def hierarchy_table(self) -> Dict[str, List[str]]:
        """Name-level aggregation: held-lock -> sorted acquired-locks.

        Instance suffixes like ``[poolname]`` are stripped so the table
        stays stable across runs; this is what DESIGN.md embeds.
        """
        agg: Dict[str, set] = {}
        for rec in self.edges():
            src = rec["src"].split("[", 1)[0]
            dst = rec["dst"].split("[", 1)[0]
            agg.setdefault(src, set()).add(dst)
        return {k: sorted(v) for k, v in sorted(agg.items())}

    def report(self) -> Dict[str, Any]:
        cycles = self.cycles()
        with self._mu:
            violations = list(self.violations)
        return {
            "acquired_total": self.acquired_total,
            "n_edges": len(self._edges),
            "cycles": cycles,
            "violations": violations,
            "table": self.hierarchy_table(),
        }

    def format_report(self, rep: Optional[Dict[str, Any]] = None) -> str:
        rep = rep or self.report()
        lines = [
            f"lock audit: {rep['acquired_total']} acquisitions, "
            f"{rep['n_edges']} order edges, {len(rep['cycles'])} cycles, "
            f"{len(rep['violations'])} violations"
        ]
        for cyc in rep["cycles"]:
            names = " -> ".join(f"{e['src']}->{e['dst']}" for e in cyc)
            lines.append(f"  CYCLE: {names}")
            for e in cyc:
                lines.append(
                    f"    edge {e['src']} -> {e['dst']} "
                    f"(x{e['count']}, thread {e['thread']}) witness:"
                )
                lines.extend(
                    "      " + ln for ln in e["stack"].rstrip().splitlines()
                )
        for v in rep["violations"]:
            lines.append(f"  VIOLATION[{v['kind']}] ({v['thread']}): {v['message']}")
            lines.extend("      " + ln for ln in v["stack"].rstrip().splitlines())
        if rep["table"]:
            lines.append("  observed order (held -> acquired):")
            for src, dsts in rep["table"].items():
                lines.append(f"    {src} -> {', '.join(dsts)}")
        return "\n".join(lines)

    def assert_clean(self) -> None:
        rep = self.report()
        if rep["cycles"] or rep["violations"]:
            raise AssertionError(self.format_report(rep))
