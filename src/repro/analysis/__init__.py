"""Concurrency-correctness analysis for the pilot control plane.

Three cooperating parts:

- :mod:`repro.analysis.locks` — instrumented Lock/RLock/Condition factory
  plus a :class:`LockAuditor` that records per-thread held-sets and
  acquisition-order edges, detects lock-order cycles with witness stacks,
  and flags blocking calls / user callbacks executed under a lock.
- :mod:`repro.analysis.lint` — repo-specific AST lint (bare threading
  locks, wall-clock in jitted step builders, the one-transfer rule,
  blocking under a held lock) with inline suppressions that require a
  written justification.
- :mod:`repro.analysis.fuzz` — deterministic schedule fuzzer: seeded
  preemption injection at lock acquire/release boundaries driving the
  six-server stress race under many seeds.

This package must stay import-light: ``locks`` is imported by every
locked module in ``core/`` and ``serving/``, so it depends only on the
stdlib.  ``fuzz`` imports the serving stack and is therefore *not*
re-exported here (import it explicitly).
"""
